//! Synthetic embedding generators matched to each paper dataset's regime.
//!
//! The OPDR experiments consume only embedding *geometry* (pairwise distances
//! and neighbor structure), so each generator controls the three knobs that
//! determine that geometry:
//!
//! * **intrinsic dimensionality** — how many latent factors drive variance;
//! * **cluster structure** — number/tightness of modes (materials data is
//!   strongly clustered; web data is a heavier-tailed mixture);
//! * **noise floor** — isotropic residual variance.
//!
//! Parameters per dataset (from the paper's qualitative descriptions: nearly
//! overlapping model fit-lines on materials ⇒ strong low-dim structure;
//! visible spread on Flickr/OmniCorpus ⇒ higher diversity):
//!
//! | dataset | clusters | intrinsic dim | noise | tail |
//! |---|---|---|---|---|
//! | materials-*  | 6–12 | 8–14  | 0.05 | gaussian |
//! | flickr30k    | 40   | 40    | 0.15 | mild heavy-tail |
//! | omnicorpus   | 120  | 64    | 0.25 | heavy-tail |
//! | esc50        | 50   | 24    | 0.10 | gaussian (one mode per class) |

use crate::data::{DatasetKind, EmbeddingSet};
use crate::util::Rng;

/// Geometry parameters of a synthetic dataset.
#[derive(Debug, Clone, Copy)]
pub struct GeometrySpec {
    /// Number of Gaussian mixture components.
    pub clusters: usize,
    /// Latent factors shared across the set (intrinsic dimensionality).
    pub intrinsic_dim: usize,
    /// Isotropic noise std added in ambient space.
    pub noise: f64,
    /// Student-t-ish tail weight: 0 = pure Gaussian, higher = heavier tails.
    pub tail: f64,
    /// Cluster center spread relative to within-cluster std.
    pub separation: f64,
}

/// The geometry spec used for a dataset kind.
pub fn spec_for(kind: DatasetKind) -> GeometrySpec {
    match kind {
        DatasetKind::MaterialsObservable => {
            GeometrySpec { clusters: 8, intrinsic_dim: 10, noise: 0.05, tail: 0.0, separation: 6.0 }
        }
        DatasetKind::MaterialsStable => {
            GeometrySpec { clusters: 6, intrinsic_dim: 8, noise: 0.05, tail: 0.0, separation: 5.0 }
        }
        DatasetKind::MaterialsMetal => {
            GeometrySpec { clusters: 12, intrinsic_dim: 14, noise: 0.06, tail: 0.0, separation: 5.5 }
        }
        DatasetKind::MaterialsMagnetic => {
            GeometrySpec { clusters: 10, intrinsic_dim: 12, noise: 0.06, tail: 0.0, separation: 5.0 }
        }
        DatasetKind::Flickr30k => {
            GeometrySpec { clusters: 40, intrinsic_dim: 40, noise: 0.15, tail: 0.5, separation: 3.0 }
        }
        DatasetKind::OmniCorpus => {
            GeometrySpec { clusters: 120, intrinsic_dim: 64, noise: 0.25, tail: 1.0, separation: 2.5 }
        }
        DatasetKind::Esc50 => {
            GeometrySpec { clusters: 50, intrinsic_dim: 24, noise: 0.10, tail: 0.0, separation: 4.0 }
        }
    }
}

/// Generate `n` synthetic embeddings of dimension `dim` for a dataset kind.
///
/// Deterministic in `(kind, n, dim, seed)`.
pub fn generate(kind: DatasetKind, n: usize, dim: usize, seed: u64) -> EmbeddingSet {
    let spec = spec_for(kind);
    generate_with_spec(kind.name(), &spec, n, dim, seed)
}

/// Generate with an explicit geometry spec (used by ablations/tests).
pub fn generate_with_spec(
    label: &str,
    spec: &GeometrySpec,
    n: usize,
    dim: usize,
    seed: u64,
) -> EmbeddingSet {
    assert!(dim > 0, "dim must be positive");
    let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
    let idim = spec.intrinsic_dim.min(dim).max(1);

    // A fixed latent→ambient linear map (the "model geometry"): idim × dim.
    let map: Vec<f64> = {
        let mut map_rng = rng.fork(1);
        let scale = 1.0 / (idim as f64).sqrt();
        (0..idim * dim).map(|_| map_rng.normal() * scale).collect()
    };

    // Cluster centers in latent space.
    let mut center_rng = rng.fork(2);
    let centers: Vec<f64> = (0..spec.clusters.max(1) * idim)
        .map(|_| center_rng.normal() * spec.separation)
        .collect();

    // Unequal cluster weights (zipf-ish for web data).
    let weights: Vec<f64> = (0..spec.clusters.max(1))
        .map(|c| 1.0 / (1.0 + c as f64).powf(0.5 + spec.tail * 0.5))
        .collect();

    let mut data = Vec::with_capacity(n * dim);
    let mut point_rng = rng.fork(3);
    for _ in 0..n {
        let c = point_rng.categorical(&weights);
        // Latent sample: cluster center + within-cluster Gaussian, with an
        // optional heavy-tail scale multiplier (approximates Student-t).
        let tail_scale = if spec.tail > 0.0 {
            // Inverse-gamma-ish multiplier: 1/sqrt(u) with u ~ Uniform(ε,1).
            let u = point_rng.uniform_range(0.15, 1.0);
            1.0 + spec.tail * (1.0 / u.sqrt() - 1.0)
        } else {
            1.0
        };
        let latent: Vec<f64> = (0..idim)
            .map(|j| centers[c * idim + j] + point_rng.normal() * tail_scale)
            .collect();
        // Ambient embedding = latent · map + noise.
        for jd in 0..dim {
            let mut acc = 0.0;
            for ji in 0..idim {
                acc += latent[ji] * map[ji * dim + jd];
            }
            acc += point_rng.normal() * spec.noise;
            data.push(acc as f32);
        }
    }
    EmbeddingSet::new(label, dim, data).expect("generator produces consistent shapes")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{covariance_matrix, eigh, Mat};

    #[test]
    fn deterministic_per_seed() {
        let a = generate(DatasetKind::Flickr30k, 20, 32, 5);
        let b = generate(DatasetKind::Flickr30k, 20, 32, 5);
        assert_eq!(a, b);
        let c = generate(DatasetKind::Flickr30k, 20, 32, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn shapes_and_finiteness() {
        for kind in DatasetKind::ALL {
            let set = generate(kind, 30, 48, 1);
            assert_eq!(set.len(), 30);
            assert_eq!(set.dim(), 48);
            assert!(set.data().iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn materials_have_low_intrinsic_dim() {
        // Eigen-spectrum of materials data should concentrate in ~intrinsic_dim
        // components.
        let set = generate(DatasetKind::MaterialsObservable, 120, 64, 3);
        let x = Mat::from_f32(set.len(), set.dim(), set.data()).unwrap();
        let cov = covariance_matrix(&x).unwrap();
        let e = eigh(&cov).unwrap();
        let total: f64 = e.values.iter().filter(|v| **v > 0.0).sum();
        let top10: f64 = e.values.iter().take(10, ).filter(|v| **v > 0.0).sum();
        assert!(top10 / total > 0.9, "top10 fraction {}", top10 / total);
    }

    #[test]
    fn omnicorpus_more_diverse_than_materials() {
        // Web data should need more components for the same variance fraction.
        let frac_needed = |kind: DatasetKind| -> usize {
            let set = generate(kind, 150, 96, 9);
            let x = Mat::from_f32(set.len(), set.dim(), set.data()).unwrap();
            let cov = covariance_matrix(&x).unwrap();
            let e = eigh(&cov).unwrap();
            let total: f64 = e.values.iter().filter(|v| **v > 0.0).sum();
            let mut acc = 0.0;
            for (i, v) in e.values.iter().enumerate() {
                acc += v.max(0.0);
                if acc / total > 0.9 {
                    return i + 1;
                }
            }
            e.values.len()
        };
        let mat = frac_needed(DatasetKind::MaterialsStable);
        let omni = frac_needed(DatasetKind::OmniCorpus);
        assert!(omni > mat, "omni {omni} should exceed materials {mat}");
    }

    #[test]
    fn clusters_exist_in_materials() {
        // Average nearest-neighbor distance must be far below average
        // pairwise distance when data is clustered.
        let set = generate(DatasetKind::MaterialsObservable, 80, 32, 11);
        let d = crate::metrics::pairwise_distances_symmetric(
            set.data(),
            set.dim(),
            crate::metrics::Metric::Euclidean,
        )
        .unwrap();
        let n = set.len();
        let mut nn_sum = 0.0f64;
        let mut all_sum = 0.0f64;
        let mut all_cnt = 0usize;
        for i in 0..n {
            let mut best = f32::INFINITY;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let dij = d[i * n + j];
                best = best.min(dij);
                all_sum += dij as f64;
                all_cnt += 1;
            }
            nn_sum += best as f64;
        }
        let mean_nn = nn_sum / n as f64;
        let mean_all = all_sum / all_cnt as f64;
        assert!(mean_nn < 0.5 * mean_all, "nn {mean_nn} vs all {mean_all}");
    }
}
