//! Artifact manifest: what `make artifacts` produced.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.toml`:
//!
//! ```toml
//! [artifacts.pairwise_topk_sqeuclidean]
//! file = "pairwise_topk_sqeuclidean.hlo.txt"
//! inputs = ["f32:32x1024", "f32:1024x1024"]
//! outputs = ["f32:32x64", "f32:32x64"]
//! ```
//!
//! Shapes are validated on every execute; only `f32` tensors cross the
//! boundary (index outputs are cast to f32 on the JAX side).

use crate::config::toml::{parse_toml, TomlValue};
use crate::error::{OpdrError, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dtype string ("f32" is the only supported interchange type).
    pub dtype: String,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse "f32:32x1024" (scalar: "f32:scalar").
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (dtype, rest) = s
            .split_once(':')
            .ok_or_else(|| OpdrError::runtime(format!("bad tensor spec `{s}`")))?;
        if dtype != "f32" {
            return Err(OpdrError::runtime(format!(
                "unsupported dtype `{dtype}` (artifacts must use f32 interchange)"
            )));
        }
        let dims = if rest == "scalar" {
            vec![]
        } else {
            rest.split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|_| OpdrError::runtime(format!("bad dim `{d}` in `{s}`")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype: dtype.to_string(), dims })
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Logical name.
    pub name: String,
    /// HLO text file (relative to the artifacts dir).
    pub file: PathBuf,
    /// Input tensor specs, positional.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs, positional (the HLO root is a tuple).
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactSpec>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.toml`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.toml");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            OpdrError::runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::from_toml_str(&src, dir)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(src: &str, dir: PathBuf) -> Result<Manifest> {
        let root = parse_toml(src)?;
        let arts = root
            .get_path("artifacts")
            .and_then(|v| v.as_table())
            .ok_or_else(|| OpdrError::runtime("manifest: missing [artifacts.*] tables"))?;
        let mut artifacts = BTreeMap::new();
        for (name, val) in arts {
            let t = val
                .as_table()
                .ok_or_else(|| OpdrError::runtime(format!("manifest: `{name}` not a table")))?;
            let file = t
                .get("file")
                .and_then(TomlValue::as_str)
                .ok_or_else(|| OpdrError::runtime(format!("manifest: `{name}` missing file")))?;
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                t.get(key)
                    .and_then(TomlValue::as_array)
                    .ok_or_else(|| OpdrError::runtime(format!("manifest: `{name}` missing {key}")))?
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .ok_or_else(|| OpdrError::runtime("manifest: spec not a string"))
                            .and_then(TensorSpec::parse)
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: PathBuf::from(file),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(Manifest { artifacts, dir })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            OpdrError::runtime(format!(
                "artifact `{name}` not in manifest (have: {})",
                self.names().join(", ")
            ))
        })
    }

    /// All artifact names.
    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
[artifacts.project]
file = "project.hlo.txt"
inputs = ["f32:32x1024", "f32:1024x1024"]
outputs = ["f32:32x1024"]

[artifacts.scalar_fn]
file = "s.hlo.txt"
inputs = ["f32:scalar"]
outputs = ["f32:scalar"]
"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_toml_str(DOC, PathBuf::from("/tmp/a")).unwrap();
        let spec = m.get("project").unwrap();
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[0].dims, vec![32, 1024]);
        assert_eq!(spec.outputs[0].elems(), 32 * 1024);
        assert_eq!(m.path_of(spec), PathBuf::from("/tmp/a/project.hlo.txt"));
        let s = m.get("scalar_fn").unwrap();
        assert!(s.inputs[0].dims.is_empty());
        assert_eq!(s.inputs[0].elems(), 1);
    }

    #[test]
    fn unknown_artifact_lists_available() {
        let m = Manifest::from_toml_str(DOC, PathBuf::from(".")).unwrap();
        let e = m.get("nope").unwrap_err().to_string();
        assert!(e.contains("project"), "{e}");
    }

    #[test]
    fn tensor_spec_validation() {
        assert!(TensorSpec::parse("f32:2x3").is_ok());
        assert!(TensorSpec::parse("f64:2").is_err());
        assert!(TensorSpec::parse("f32:2xbad").is_err());
        assert!(TensorSpec::parse("noseparator").is_err());
    }

    #[test]
    fn missing_sections_error() {
        assert!(Manifest::from_toml_str("x = 1", PathBuf::from(".")).is_err());
        let bad = "[artifacts.a]\nfile = \"a.hlo\"\ninputs = [\"f32:2\"]";
        assert!(Manifest::from_toml_str(bad, PathBuf::from(".")).is_err()); // no outputs
    }
}
