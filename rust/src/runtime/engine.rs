//! The PJRT execution engine.
//!
//! Wraps `xla::PjRtClient` (CPU): loads HLO text artifacts on demand, caches
//! compiled executables, and exposes a typed f32 execute. Follows the
//! reference wiring of /opt/xla-example/load_hlo.rs; outputs are always
//! 1-tuples or n-tuples (the lowering uses `return_tuple=True`).

use crate::error::{OpdrError, Result};
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::ArrayF32;
use std::cell::RefCell;
use std::collections::HashMap;

/// Compiles and runs AOT artifacts on the PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.names())
            .finish()
    }
}

impl Engine {
    /// Create an engine over an artifacts directory (must contain
    /// `manifest.toml`; see `make artifacts`).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Eagerly compile an artifact (otherwise compiled on first execute).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.compiled(name).map(|_| ())
    }

    /// Eagerly compile every artifact in the manifest.
    pub fn warmup_all(&self) -> Result<()> {
        for name in self.manifest.names() {
            self.warmup(&name)?;
        }
        Ok(())
    }

    fn compiled(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?;
        let path = self.manifest.path_of(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| OpdrError::runtime("non-UTF8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with positional f32 inputs; returns positional
    /// f32 outputs. Shapes are validated against the manifest.
    pub fn execute(&self, name: &str, inputs: &[ArrayF32]) -> Result<Vec<ArrayF32>> {
        let spec = self.manifest.get(name)?.clone();
        self.validate_inputs(&spec, inputs)?;
        self.compiled(name)?;

        // Build input literals.
        let mut literals = Vec::with_capacity(inputs.len());
        for arr in inputs {
            let lit = xla::Literal::vec1(&arr.data);
            let dims: Vec<i64> = arr.shape.iter().map(|&d| d as i64).collect();
            let lit = if arr.shape.len() == 1 { lit } else { lit.reshape(&dims)? };
            literals.push(lit);
        }

        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("compiled() just populated the cache");
        let result = exe.execute::<xla::Literal>(&literals)?;
        let buffer = &result[0][0];
        let root = buffer.to_literal_sync()?;
        drop(cache);

        // Root is a tuple of outputs (return_tuple=True on the python side).
        let elements = root.to_tuple()?;
        if elements.len() != spec.outputs.len() {
            return Err(OpdrError::runtime(format!(
                "artifact `{name}`: manifest declares {} outputs, HLO returned {}",
                spec.outputs.len(),
                elements.len()
            )));
        }
        let mut out = Vec::with_capacity(elements.len());
        for (lit, ospec) in elements.into_iter().zip(&spec.outputs) {
            let data = lit.to_vec::<f32>()?;
            if data.len() != ospec.elems() {
                return Err(OpdrError::runtime(format!(
                    "artifact `{name}`: output has {} elems, manifest says {}",
                    data.len(),
                    ospec.elems()
                )));
            }
            out.push(ArrayF32::new(data, ospec.dims.clone())?);
        }
        Ok(out)
    }

    fn validate_inputs(&self, spec: &ArtifactSpec, inputs: &[ArrayF32]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            return Err(OpdrError::runtime(format!(
                "artifact `{}`: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (arr, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if arr.shape != ispec.dims {
                return Err(OpdrError::runtime(format!(
                    "artifact `{}` input {i}: shape {:?} != manifest {:?}",
                    spec.name, arr.shape, ispec.dims
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    // Engine tests that need real artifacts live in rust/tests/runtime_it.rs
    // (they require `make artifacts`). Here: manifest-level validation only.

    fn fake_manifest() -> Manifest {
        Manifest::from_toml_str(
            r#"
[artifacts.toy]
file = "toy.hlo.txt"
inputs = ["f32:2x2"]
outputs = ["f32:2x2"]
"#,
            PathBuf::from("/nonexistent"),
        )
        .unwrap()
    }

    #[test]
    fn input_validation_rejects_wrong_arity_and_shape() {
        let m = fake_manifest();
        let spec = m.get("toy").unwrap();
        // Build a client-less check through the private fn via a tiny shim:
        // validate logic is pure, so replicate through Engine API would need
        // a client; instead verify TensorSpec comparison logic here.
        let ok = ArrayF32::zeros(&[2, 2]);
        let bad = ArrayF32::zeros(&[2, 3]);
        assert_eq!(spec.inputs[0].dims, ok.shape);
        assert_ne!(spec.inputs[0].dims, bad.shape);
    }

    #[test]
    fn missing_artifacts_dir_errors_helpfully() {
        let e = Engine::new("/definitely/not/here").unwrap_err().to_string();
        assert!(e.contains("make artifacts"), "{e}");
    }
}
