//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them.
//!
//! The build-time layer (`python/compile/aot.py`) lowers every JAX/Pallas
//! graph to **HLO text** (not serialized `HloModuleProto` — the crate's
//! xla_extension 0.5.1 rejects jax≥0.5 64-bit-id protos) plus a
//! `manifest.toml` describing names, files and shapes. [`Engine`] compiles
//! each module once on the PJRT CPU client and caches the executable; all
//! artifact I/O is `f32` tensors ([`ArrayF32`]).
//!
//! The engine is deliberately `!Sync`: the coordinator gives it to a single
//! executor thread (see [`crate::coordinator`]), keeping PJRT single-threaded
//! and the request path allocation-predictable.

pub mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

use crate::error::{OpdrError, Result};

/// A dense row-major `f32` tensor with shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayF32 {
    /// Row-major payload.
    pub data: Vec<f32>,
    /// Dimensions.
    pub shape: Vec<usize>,
}

impl ArrayF32 {
    /// Build, validating `data.len() == product(shape)`.
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(OpdrError::shape(format!(
                "ArrayF32: shape {shape:?} wants {n} elems, got {}",
                data.len()
            )));
        }
        Ok(ArrayF32 { data, shape })
    }

    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        ArrayF32 { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Copy a 2-D row-major block into the top-left corner of a zero-padded
    /// tensor of shape `[rows, cols]` — the padding convention every
    /// fixed-shape artifact relies on (zero-padding is distance-exact for the
    /// supported metrics).
    pub fn padded_2d(block: &[f32], src_rows: usize, src_cols: usize, rows: usize, cols: usize) -> Result<Self> {
        if src_rows > rows || src_cols > cols {
            return Err(OpdrError::shape(format!(
                "padded_2d: source {src_rows}x{src_cols} exceeds target {rows}x{cols}"
            )));
        }
        if block.len() != src_rows * src_cols {
            return Err(OpdrError::shape("padded_2d: block length mismatch"));
        }
        let mut out = ArrayF32::zeros(&[rows, cols]);
        for r in 0..src_rows {
            out.data[r * cols..r * cols + src_cols]
                .copy_from_slice(&block[r * src_cols..(r + 1) * src_cols]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn array_validation() {
        assert!(ArrayF32::new(vec![0.0; 6], vec![2, 3]).is_ok());
        assert!(ArrayF32::new(vec![0.0; 5], vec![2, 3]).is_err());
        let z = ArrayF32::zeros(&[4, 2]);
        assert_eq!(z.len(), 8);
    }

    #[test]
    fn padding_places_block_top_left() {
        let block = [1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let p = ArrayF32::padded_2d(&block, 2, 2, 3, 4).unwrap();
        assert_eq!(p.shape, vec![3, 4]);
        assert_eq!(p.data[0], 1.0);
        assert_eq!(p.data[1], 2.0);
        assert_eq!(p.data[4], 3.0);
        assert_eq!(p.data[5], 4.0);
        // Everything else zero.
        assert_eq!(p.data.iter().filter(|&&x| x != 0.0).count(), 4);
    }

    #[test]
    fn padding_rejects_oversize() {
        let block = [0.0f32; 4];
        assert!(ArrayF32::padded_2d(&block, 2, 2, 1, 4).is_err());
        assert!(ArrayF32::padded_2d(&block, 2, 3, 4, 4).is_err());
    }
}
