//! Dimension-reduction methods.
//!
//! The paper integrates OPDR with PCA and MDS; this module implements both
//! (PCA with covariance- and Gram-trick fit paths, classical Torgerson MDS,
//! and iterative SMACOF metric MDS), plus Gaussian random projection as a
//! Johnson–Lindenstrauss baseline and an identity reducer for sanity checks.
//!
//! All reducers consume row-major `f32` data (`m` samples × `d` dims) and
//! produce row-major `f32` output (`m` × `target_dim`). Fit-time math runs in
//! `f64` through [`crate::linalg`].

pub mod mds;
pub mod pca;
pub mod random_proj;

pub use mds::{ClassicalMds, SmacofMds};
pub use pca::{Pca, PcaModel};
pub use random_proj::GaussianRandomProjection;

use crate::error::{OpdrError, Result};

/// A dimension-reduction method: maps `m×d` data to `m×target_dim`.
pub trait DimReducer {
    /// Fit on `data` and return the reduced coordinates.
    ///
    /// `data` is row-major with `m = data.len() / dim` samples. Errors if
    /// `target_dim > dim` or `target_dim == 0` or shapes are inconsistent.
    fn fit_transform(&self, data: &[f32], dim: usize, target_dim: usize) -> Result<Vec<f32>>;

    /// Human-readable method name.
    fn name(&self) -> &'static str;
}

/// Validate common reducer preconditions; returns the sample count.
pub(crate) fn check_shapes(data: &[f32], dim: usize, target_dim: usize) -> Result<usize> {
    if dim == 0 {
        return Err(OpdrError::shape("reducer: dim must be > 0"));
    }
    if data.len() % dim != 0 {
        return Err(OpdrError::shape("reducer: data not a multiple of dim"));
    }
    if target_dim == 0 {
        return Err(OpdrError::shape("reducer: target_dim must be > 0"));
    }
    if target_dim > dim {
        return Err(OpdrError::shape(format!(
            "reducer: target_dim {target_dim} > input dim {dim}"
        )));
    }
    let m = data.len() / dim;
    if m == 0 {
        return Err(OpdrError::shape("reducer: no samples"));
    }
    Ok(m)
}

/// Reducer selector for configs / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReducerKind {
    /// Principal Component Analysis.
    Pca,
    /// Classical (Torgerson) MDS.
    ClassicalMds,
    /// SMACOF iterative metric MDS.
    Smacof,
    /// Gaussian random projection (JL baseline).
    RandomProjection,
    /// Identity/truncation (sanity baseline).
    Identity,
}

impl ReducerKind {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<ReducerKind> {
        match s.to_ascii_lowercase().as_str() {
            "pca" => Some(ReducerKind::Pca),
            "mds" | "classical-mds" | "cmds" => Some(ReducerKind::ClassicalMds),
            "smacof" | "smacof-mds" => Some(ReducerKind::Smacof),
            "random" | "random-projection" | "rp" | "jl" => Some(ReducerKind::RandomProjection),
            "identity" | "truncate" => Some(ReducerKind::Identity),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            ReducerKind::Pca => "pca",
            ReducerKind::ClassicalMds => "mds",
            ReducerKind::Smacof => "smacof",
            ReducerKind::RandomProjection => "random-projection",
            ReducerKind::Identity => "identity",
        }
    }

    /// Instantiate with a seed (only random projection consumes it).
    pub fn build(&self, seed: u64) -> Box<dyn DimReducer> {
        match self {
            ReducerKind::Pca => Box::new(Pca::new()),
            ReducerKind::ClassicalMds => Box::new(ClassicalMds::new()),
            ReducerKind::Smacof => Box::new(SmacofMds::default()),
            ReducerKind::RandomProjection => Box::new(GaussianRandomProjection::new(seed)),
            ReducerKind::Identity => Box::new(IdentityReducer),
        }
    }
}

/// Truncation baseline: keep the first `target_dim` coordinates.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityReducer;

impl DimReducer for IdentityReducer {
    fn fit_transform(&self, data: &[f32], dim: usize, target_dim: usize) -> Result<Vec<f32>> {
        let m = check_shapes(data, dim, target_dim)?;
        let mut out = Vec::with_capacity(m * target_dim);
        for i in 0..m {
            out.extend_from_slice(&data[i * dim..i * dim + target_dim]);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "identity"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [
            ReducerKind::Pca,
            ReducerKind::ClassicalMds,
            ReducerKind::Smacof,
            ReducerKind::RandomProjection,
            ReducerKind::Identity,
        ] {
            assert_eq!(ReducerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ReducerKind::parse("nope"), None);
    }

    #[test]
    fn identity_truncates() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let out = IdentityReducer.fit_transform(&data, 3, 2).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 4.0, 5.0]);
    }

    #[test]
    fn shape_checks() {
        let data = [1.0f32; 6];
        assert!(check_shapes(&data, 0, 1).is_err());
        assert!(check_shapes(&data, 4, 1).is_err()); // 6 % 4 != 0
        assert!(check_shapes(&data, 3, 0).is_err());
        assert!(check_shapes(&data, 3, 4).is_err());
        assert_eq!(check_shapes(&data, 3, 2).unwrap(), 2);
        assert!(check_shapes(&[], 3, 2).is_err());
    }

    #[test]
    fn build_dispatches() {
        for kind in [
            ReducerKind::Pca,
            ReducerKind::ClassicalMds,
            ReducerKind::Smacof,
            ReducerKind::RandomProjection,
            ReducerKind::Identity,
        ] {
            let r = kind.build(1);
            // identity/mds names map through.
            assert!(!r.name().is_empty());
        }
    }
}
