//! Principal Component Analysis with two fit paths.
//!
//! * **Covariance path** (`d ≤ m`): eigendecompose the d×d covariance.
//! * **Gram-trick path** (`d > m`): eigendecompose the m×m centered Gram
//!   matrix — identical projections, much cheaper for the paper's regime
//!   (m ≤ 300 samples of 512–2816-dim embeddings).
//!
//! The fitted [`PcaModel`] exposes `project` for out-of-sample vectors, which
//! is what the serving coordinator and the `pca_project` HLO artifact use.

use crate::error::{OpdrError, Result};
use crate::linalg::{center_columns, eigh, Mat};
use crate::reduction::{check_shapes, DimReducer};

/// PCA reducer (stateless config; fitting returns a [`PcaModel`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct Pca {
    /// Force the covariance path even when the Gram trick would be cheaper
    /// (used by the ablation bench).
    pub force_covariance: bool,
}

impl Pca {
    /// New PCA with automatic path selection.
    pub fn new() -> Self {
        Pca { force_covariance: false }
    }

    /// Fit a model retaining `target_dim` components.
    pub fn fit(&self, data: &[f32], dim: usize, target_dim: usize) -> Result<PcaModel> {
        let m = check_shapes(data, dim, target_dim)?;
        if m < 2 {
            return Err(OpdrError::shape("pca: need at least 2 samples"));
        }
        let x = Mat::from_f32(m, dim, data)?;
        let (xc, means) = center_columns(&x);

        // Rank of centered data ≤ m-1; components beyond that are arbitrary
        // null-space directions, still orthonormal, and we keep them so output
        // dims are as requested (variance 0 on those axes).
        let use_gram = dim > m && !self.force_covariance;
        let (components, variances) = if use_gram {
            // Gram trick: XcXcᵀ = U Λ Uᵀ (m×m); components V = Xcᵀ U Λ^{-1/2}.
            let g = xc.matmul(&xc.transpose())?;
            let eg = eigh(&g)?;
            let mut comp = Mat::zeros(dim, target_dim);
            let mut vars = Vec::with_capacity(target_dim);
            for c in 0..target_dim {
                let lam = eg.values.get(c).copied().unwrap_or(0.0).max(0.0);
                vars.push(lam / (m as f64 - 1.0));
                if lam > 1e-10 {
                    let scale = 1.0 / lam.sqrt();
                    // v_c = Xcᵀ u_c / sqrt(λ)
                    for j in 0..dim {
                        let mut acc = 0.0;
                        for i in 0..m {
                            acc += xc[(i, j)] * eg.vectors[(i, c)];
                        }
                        comp[(j, c)] = acc * scale;
                    }
                } else {
                    // Deterministic fallback basis vector for null components,
                    // orthogonalized against previous columns (Gram–Schmidt on e_c).
                    let mut v = vec![0.0; dim];
                    v[c % dim] = 1.0;
                    for prev in 0..c {
                        let dot: f64 = (0..dim).map(|j| v[j] * comp[(j, prev)]).sum();
                        for j in 0..dim {
                            v[j] -= dot * comp[(j, prev)];
                        }
                    }
                    let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
                    if norm > 1e-12 {
                        for (j, vj) in v.iter().enumerate() {
                            comp[(j, c)] = vj / norm;
                        }
                    }
                }
            }
            (comp, vars)
        } else {
            // Covariance path.
            let mut cov = xc.transpose().matmul(&xc)?;
            cov.scale(1.0 / (m as f64 - 1.0));
            let ec = eigh(&cov)?;
            let mut comp = Mat::zeros(dim, target_dim);
            let mut vars = Vec::with_capacity(target_dim);
            for c in 0..target_dim {
                vars.push(ec.values[c].max(0.0));
                for j in 0..dim {
                    comp[(j, c)] = ec.vectors[(j, c)];
                }
            }
            (comp, vars)
        };

        Ok(PcaModel { dim, target_dim, means, components, explained_variance: variances })
    }
}

impl DimReducer for Pca {
    fn fit_transform(&self, data: &[f32], dim: usize, target_dim: usize) -> Result<Vec<f32>> {
        let model = self.fit(data, dim, target_dim)?;
        model.project(data)
    }

    fn name(&self) -> &'static str {
        "pca"
    }
}

/// A fitted PCA model: projection matrix + column means.
#[derive(Debug, Clone)]
pub struct PcaModel {
    dim: usize,
    target_dim: usize,
    means: Vec<f64>,
    /// d × target_dim, orthonormal columns.
    components: Mat,
    /// Per-component explained variance, descending.
    pub explained_variance: Vec<f64>,
}

impl PcaModel {
    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Output dimensionality.
    pub fn target_dim(&self) -> usize {
        self.target_dim
    }

    /// Column means subtracted before projection.
    pub fn means(&self) -> &[f64] {
        &self.means
    }

    /// Projection matrix as a row-major f32 buffer (d × target_dim), the
    /// layout the `pca_project` HLO artifact consumes.
    pub fn components_f32(&self) -> Vec<f32> {
        self.components.data().iter().map(|&x| x as f32).collect()
    }

    /// Project out-of-sample row-major data (any number of rows).
    pub fn project(&self, data: &[f32]) -> Result<Vec<f32>> {
        if data.len() % self.dim != 0 {
            return Err(OpdrError::shape("pca project: bad input shape"));
        }
        let m = data.len() / self.dim;
        let mut out = vec![0.0f32; m * self.target_dim];
        for i in 0..m {
            let row = &data[i * self.dim..(i + 1) * self.dim];
            for c in 0..self.target_dim {
                let mut acc = 0.0f64;
                for j in 0..self.dim {
                    acc += (row[j] as f64 - self.means[j]) * self.components[(j, c)];
                }
                out[i * self.target_dim + c] = acc as f32;
            }
        }
        Ok(out)
    }

    /// Fraction of total variance captured (0..1), when total is known.
    pub fn explained_variance_ratio(&self, total_variance: f64) -> Vec<f64> {
        if total_variance <= 0.0 {
            return vec![0.0; self.explained_variance.len()];
        }
        self.explained_variance.iter().map(|v| v / total_variance).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Data with a dominant direction along (1,1,...)/√d plus small noise.
    fn anisotropic(m: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(m * d);
        for _ in 0..m {
            let t = rng.normal() * 10.0;
            for j in 0..d {
                let dir = 1.0 / (d as f64).sqrt();
                data.push((t * dir + 0.1 * rng.normal() + j as f64 * 0.0) as f32);
            }
        }
        data
    }

    #[test]
    fn first_component_captures_dominant_direction() {
        let d = 6;
        let data = anisotropic(50, d, 1);
        let model = Pca::new().fit(&data, d, 2).unwrap();
        // Component 0 ≈ ±(1,..,1)/√d.
        let comp = model.components_f32();
        let expected = 1.0 / (d as f32).sqrt();
        let sign = comp[0].signum();
        for j in 0..d {
            let cj = comp[j * 2]; // row-major d×2, column 0
            assert!((cj - sign * expected).abs() < 0.05, "comp[{j}]={cj}");
        }
        assert!(model.explained_variance[0] > 10.0 * model.explained_variance[1]);
    }

    #[test]
    fn gram_and_covariance_paths_agree() {
        let mut rng = Rng::new(9);
        let (m, d) = (12, 30); // d > m triggers Gram path
        let data = rng.normal_vec_f32(m * d);
        let gram = Pca::new().fit_transform(&data, d, 5).unwrap();
        let cov = Pca { force_covariance: true }.fit_transform(&data, d, 5).unwrap();
        // Components are sign-ambiguous; compare per-column up to sign.
        for c in 0..5 {
            let col_g: Vec<f32> = (0..m).map(|i| gram[i * 5 + c]).collect();
            let col_c: Vec<f32> = (0..m).map(|i| cov[i * 5 + c]).collect();
            let dot: f32 = col_g.iter().zip(&col_c).map(|(a, b)| a * b).sum();
            let sign = dot.signum();
            for i in 0..m {
                assert!(
                    (col_g[i] - sign * col_c[i]).abs() < 1e-2,
                    "col {c} row {i}: {} vs {}",
                    col_g[i],
                    sign * col_c[i]
                );
            }
        }
    }

    #[test]
    fn full_dim_pca_preserves_distances() {
        // target_dim == dim (and m > d): PCA is a rigid rotation — pairwise
        // distances are exactly preserved.
        let mut rng = Rng::new(3);
        let (m, d) = (20, 5);
        let data = rng.normal_vec_f32(m * d);
        let out = Pca::new().fit_transform(&data, d, d).unwrap();
        let din = crate::metrics::pairwise_distances_symmetric(&data, d, crate::metrics::Metric::Euclidean).unwrap();
        let dout = crate::metrics::pairwise_distances_symmetric(&out, d, crate::metrics::Metric::Euclidean).unwrap();
        for (a, b) in din.iter().zip(&dout) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn projection_of_training_mean_is_zero() {
        let mut rng = Rng::new(5);
        let (m, d) = (15, 8);
        let data = rng.normal_vec_f32(m * d);
        let model = Pca::new().fit(&data, d, 3).unwrap();
        let mean_f32: Vec<f32> = model.means().iter().map(|&x| x as f32).collect();
        let proj = model.project(&mean_f32).unwrap();
        for v in proj {
            assert!(v.abs() < 1e-5);
        }
    }

    #[test]
    fn explained_variance_descending() {
        let data = anisotropic(40, 10, 7);
        let model = Pca::new().fit(&data, 10, 6).unwrap();
        for w in model.explained_variance.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
    }

    #[test]
    fn rejects_single_sample_and_bad_dims() {
        let data = [1.0f32; 8];
        assert!(Pca::new().fit(&data, 8, 2).is_err()); // m = 1
        assert!(Pca::new().fit(&data, 4, 5).is_err()); // target > dim
    }

    #[test]
    fn out_of_sample_projection_shape() {
        let mut rng = Rng::new(2);
        let data = rng.normal_vec_f32(10 * 6);
        let model = Pca::new().fit(&data, 6, 2).unwrap();
        let queries = rng.normal_vec_f32(3 * 6);
        let proj = model.project(&queries).unwrap();
        assert_eq!(proj.len(), 3 * 2);
        assert!(model.project(&[0.0; 7]).is_err());
    }

    #[test]
    fn target_dim_beyond_rank_still_orthonormal_output() {
        // m=4 samples in d=10: rank ≤ 3, ask for 6 dims via Gram path.
        let mut rng = Rng::new(21);
        let data = rng.normal_vec_f32(4 * 10);
        let model = Pca::new().fit(&data, 10, 6).unwrap();
        let comp = model.components_f32(); // 10×6
        // Columns roughly orthonormal.
        for a in 0..6 {
            for b in a..6 {
                let dot: f32 = (0..10).map(|j| comp[j * 6 + a] * comp[j * 6 + b]).sum();
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-3, "({a},{b}) dot={dot}");
            }
        }
    }
}
