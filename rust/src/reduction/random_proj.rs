//! Gaussian random projection — the Johnson–Lindenstrauss baseline.
//!
//! Not evaluated in the paper's figures but included as the natural ablation:
//! JL preserves *distances* in expectation yet ignores data structure, so its
//! accuracy-vs-n/m curve sits well below PCA's — a useful sanity contrast for
//! the OPDR claim that structure-aware reduction preserves neighbor sets
//! faster.

use crate::error::Result;
use crate::reduction::{check_shapes, DimReducer};
use crate::util::Rng;

/// Dense Gaussian random projection, entries N(0, 1/target_dim).
#[derive(Debug, Clone, Copy)]
pub struct GaussianRandomProjection {
    /// Seed for the projection matrix.
    pub seed: u64,
}

impl GaussianRandomProjection {
    /// New projection with the given seed.
    pub fn new(seed: u64) -> Self {
        GaussianRandomProjection { seed }
    }

    /// Generate the d×target_dim projection matrix (row-major f32).
    pub fn matrix(&self, dim: usize, target_dim: usize) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ 0x5EED_CAFE);
        let scale = 1.0 / (target_dim as f64).sqrt();
        (0..dim * target_dim).map(|_| (rng.normal() * scale) as f32).collect()
    }
}

impl DimReducer for GaussianRandomProjection {
    fn fit_transform(&self, data: &[f32], dim: usize, target_dim: usize) -> Result<Vec<f32>> {
        let m = check_shapes(data, dim, target_dim)?;
        let proj = self.matrix(dim, target_dim);
        let mut out = vec![0.0f32; m * target_dim];
        for i in 0..m {
            let row = &data[i * dim..(i + 1) * dim];
            let orow = &mut out[i * target_dim..(i + 1) * target_dim];
            for (j, &x) in row.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let prow = &proj[j * target_dim..(j + 1) * target_dim];
                for c in 0..target_dim {
                    orow[c] += x * prow[c];
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "random-projection"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{pairwise_distances_symmetric, Metric};
    use crate::util::Rng;

    #[test]
    fn deterministic_for_seed() {
        let mut rng = Rng::new(1);
        let data = rng.normal_vec_f32(10 * 16);
        let a = GaussianRandomProjection::new(5).fit_transform(&data, 16, 4).unwrap();
        let b = GaussianRandomProjection::new(5).fit_transform(&data, 16, 4).unwrap();
        assert_eq!(a, b);
        let c = GaussianRandomProjection::new(6).fit_transform(&data, 16, 4).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn jl_distance_preservation_in_expectation() {
        // With a healthy target dim, relative distance errors should be modest.
        let mut rng = Rng::new(2);
        let m = 20;
        let dim = 256;
        let data = rng.normal_vec_f32(m * dim);
        let out = GaussianRandomProjection::new(3).fit_transform(&data, dim, 128).unwrap();
        let din = pairwise_distances_symmetric(&data, dim, Metric::Euclidean).unwrap();
        let dout = pairwise_distances_symmetric(&out, 128, Metric::Euclidean).unwrap();
        let mut rel_errs = Vec::new();
        for i in 0..m {
            for j in (i + 1)..m {
                let a = din[i * m + j];
                let b = dout[i * m + j];
                rel_errs.push(((a - b) / a).abs() as f64);
            }
        }
        let mean_err = crate::util::float::mean(&rel_errs);
        assert!(mean_err < 0.15, "mean rel err {mean_err}");
    }

    #[test]
    fn shape_validation() {
        let data = [0.0f32; 8];
        assert!(GaussianRandomProjection::new(0).fit_transform(&data, 4, 8).is_err());
    }
}
