//! Multidimensional scaling: classical (Torgerson) and SMACOF.
//!
//! Classical MDS double-centers the squared-distance matrix and embeds via
//! the top eigenpairs — exact for Euclidean inputs. SMACOF iteratively
//! minimizes metric stress by majorization; it is the variant that actually
//! behaves like sklearn's `MDS` (the paper's comparator), including its
//! tendency to plateau below PCA's neighborhood-preservation accuracy
//! (Figs 10–12).

use crate::error::{OpdrError, Result};
use crate::linalg::{double_center, eigh, Mat};
use crate::metrics::{pairwise_distances_symmetric, Metric};
use crate::reduction::{check_shapes, DimReducer};
use crate::util::Rng;

/// Classical (Torgerson 1952) MDS.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassicalMds {}

impl ClassicalMds {
    /// New classical MDS.
    pub fn new() -> Self {
        ClassicalMds {}
    }

    /// Embed from a precomputed squared-distance matrix (m×m).
    pub fn embed_from_sq_distances(&self, d_sq: &Mat, target_dim: usize) -> Result<Vec<f32>> {
        let m = d_sq.rows();
        if target_dim == 0 || target_dim > m {
            return Err(OpdrError::shape("cmds: bad target_dim"));
        }
        let b = double_center(d_sq)?;
        let e = eigh(&b)?;
        let mut out = vec![0.0f32; m * target_dim];
        for c in 0..target_dim {
            let lam = e.values[c].max(0.0);
            let scale = lam.sqrt();
            for i in 0..m {
                out[i * target_dim + c] = (e.vectors[(i, c)] * scale) as f32;
            }
        }
        Ok(out)
    }
}

impl DimReducer for ClassicalMds {
    fn fit_transform(&self, data: &[f32], dim: usize, target_dim: usize) -> Result<Vec<f32>> {
        let m = check_shapes(data, dim, target_dim)?;
        let d = pairwise_distances_symmetric(data, dim, Metric::SqEuclidean)?;
        let d_sq = Mat::from_f32(m, m, &d)?;
        self.embed_from_sq_distances(&d_sq, target_dim)
    }

    fn name(&self) -> &'static str {
        "mds"
    }
}

/// Initialization strategy for SMACOF.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SmacofInit {
    /// Random Gaussian start — sklearn's default behaviour (the paper's
    /// comparator). Converges to local stress minima, which is exactly why
    /// MDS plateaus below PCA in Figs 10–12.
    Random,
    /// Warm start from classical MDS — converges further; used when SMACOF
    /// is wanted as a *good* embedder rather than as the paper's baseline.
    Classical,
}

/// SMACOF metric MDS (stress majorization).
///
/// Defaults mirror sklearn's `MDS` (random init, `max_iter=300`,
/// `eps=1e-3`-style relative stopping), since that is what the paper ran.
#[derive(Debug, Clone, Copy)]
pub struct SmacofMds {
    /// Maximum majorization iterations.
    pub max_iters: usize,
    /// Relative stress-improvement stopping threshold.
    pub eps: f64,
    /// Seed for random initialization.
    pub seed: u64,
    /// Initialization strategy.
    pub init: SmacofInit,
}

impl Default for SmacofMds {
    fn default() -> Self {
        SmacofMds { max_iters: 300, eps: 1e-4, seed: 0, init: SmacofInit::Random }
    }
}

impl SmacofMds {
    /// Classical-MDS-initialized variant (better embeddings, not the paper's
    /// sklearn baseline).
    pub fn warm_started() -> Self {
        SmacofMds { init: SmacofInit::Classical, eps: 1e-6, ..Default::default() }
    }
}

impl SmacofMds {
    /// Raw stress `Σ_{i<j} (d_ij − δ_ij)²` of a configuration against target
    /// distances `delta` (m×m, plain distances not squared).
    pub fn stress(coords: &[f32], target_dim: usize, delta: &Mat) -> f64 {
        let m = delta.rows();
        let mut s = 0.0;
        for i in 0..m {
            for j in (i + 1)..m {
                let d = Metric::Euclidean.distance(
                    &coords[i * target_dim..(i + 1) * target_dim],
                    &coords[j * target_dim..(j + 1) * target_dim],
                ) as f64;
                let diff = d - delta[(i, j)];
                s += diff * diff;
            }
        }
        s
    }

    fn guttman_step(coords: &[f32], target_dim: usize, delta: &Mat) -> Vec<f32> {
        // X' = B(X) X / m  with B(X) the Guttman transform matrix.
        let m = delta.rows();
        let mut next = vec![0.0f64; m * target_dim];
        // Compute B entries on the fly.
        let mut b_diag = vec![0.0f64; m];
        let mut bx = vec![0.0f64; m * target_dim];
        for i in 0..m {
            for j in 0..m {
                if i == j {
                    continue;
                }
                let d = Metric::Euclidean.distance(
                    &coords[i * target_dim..(i + 1) * target_dim],
                    &coords[j * target_dim..(j + 1) * target_dim],
                ) as f64;
                let b_ij = if d > 1e-12 { -delta[(i, j)] / d } else { 0.0 };
                b_diag[i] -= b_ij;
                for c in 0..target_dim {
                    bx[i * target_dim + c] += b_ij * coords[j * target_dim + c] as f64;
                }
            }
        }
        for i in 0..m {
            for c in 0..target_dim {
                bx[i * target_dim + c] += b_diag[i] * coords[i * target_dim + c] as f64;
                next[i * target_dim + c] = bx[i * target_dim + c] / m as f64;
            }
        }
        next.into_iter().map(|x| x as f32).collect()
    }
}

impl DimReducer for SmacofMds {
    fn fit_transform(&self, data: &[f32], dim: usize, target_dim: usize) -> Result<Vec<f32>> {
        let m = check_shapes(data, dim, target_dim)?;
        let dist = pairwise_distances_symmetric(data, dim, Metric::Euclidean)?;
        let delta = Mat::from_f32(m, m, &dist)?;

        let mut coords = match self.init {
            SmacofInit::Random => {
                // sklearn-style: random Gaussian start scaled to the data.
                let scale = {
                    let mut s = 0.0f64;
                    let mut cnt = 0usize;
                    for i in 0..m {
                        for j in (i + 1)..m {
                            s += delta[(i, j)];
                            cnt += 1;
                        }
                    }
                    (s / cnt.max(1) as f64) as f32 * 0.5
                };
                let mut rng = Rng::new(self.seed);
                let mut v = rng.normal_vec_f32(m * target_dim);
                for x in &mut v {
                    *x *= scale;
                }
                v
            }
            SmacofInit::Classical => {
                let dsq_vec: Vec<f32> = dist.iter().map(|&x| x * x).collect();
                let d_sq = Mat::from_f32(m, m, &dsq_vec)?;
                match ClassicalMds::new().embed_from_sq_distances(&d_sq, target_dim) {
                    Ok(c) => c,
                    Err(_) => {
                        let mut rng = Rng::new(self.seed);
                        rng.normal_vec_f32(m * target_dim)
                    }
                }
            }
        };

        let mut prev_stress = Self::stress(&coords, target_dim, &delta);
        for _ in 0..self.max_iters {
            coords = Self::guttman_step(&coords, target_dim, &delta);
            let stress = Self::stress(&coords, target_dim, &delta);
            if prev_stress <= 1e-18 {
                break;
            }
            if (prev_stress - stress).abs() / prev_stress.max(1e-18) < self.eps {
                prev_stress = stress;
                break;
            }
            prev_stress = stress;
        }
        let _ = prev_stress;
        Ok(coords)
    }

    fn name(&self) -> &'static str {
        "smacof"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metric;
    use crate::util::Rng;

    /// Max relative distance distortion between two configurations.
    fn max_distortion(a: &[f32], da: usize, b: &[f32], db: usize, m: usize) -> f32 {
        let mut worst = 0.0f32;
        for i in 0..m {
            for j in (i + 1)..m {
                let d1 = Metric::Euclidean.distance(&a[i * da..(i + 1) * da], &a[j * da..(j + 1) * da]);
                let d2 = Metric::Euclidean.distance(&b[i * db..(i + 1) * db], &b[j * db..(j + 1) * db]);
                let denom = d1.max(1e-6);
                worst = worst.max((d1 - d2).abs() / denom);
            }
        }
        worst
    }

    /// Points genuinely 2-dimensional, embedded (rotated) into 6 dims.
    fn planar_in_6d(m: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(m * 6);
        for _ in 0..m {
            let (u, v) = (rng.normal() * 3.0, rng.normal() * 2.0);
            // Fixed orthonormal-ish embedding of the plane into 6D.
            let row = [
                0.5 * u + 0.1 * v,
                0.5 * u - 0.1 * v,
                0.3 * v,
                -0.3 * v + 0.2 * u,
                0.4 * u,
                0.6 * v,
            ];
            data.extend(row.iter().map(|&x| x as f32));
        }
        data
    }

    #[test]
    fn classical_mds_exact_for_intrinsic_dim() {
        // 2D data in 6D: a 2-dim classical MDS must reproduce distances ~exactly.
        let m = 15;
        let data = planar_in_6d(m, 1);
        let out = ClassicalMds::new().fit_transform(&data, 6, 2).unwrap();
        assert!(max_distortion(&data, 6, &out, 2, m) < 1e-3);
    }

    #[test]
    fn classical_mds_full_dim_preserves_distances() {
        let mut rng = Rng::new(4);
        let m = 10;
        let data = rng.normal_vec_f32(m * 4);
        let out = ClassicalMds::new().fit_transform(&data, 4, 4).unwrap();
        assert!(max_distortion(&data, 4, &out, 4, m) < 1e-3);
    }

    #[test]
    fn smacof_reduces_stress_from_random() {
        let mut rng = Rng::new(6);
        let m = 12;
        let data = rng.normal_vec_f32(m * 8);
        let dist = pairwise_distances_symmetric(&data, 8, Metric::Euclidean).unwrap();
        let delta = Mat::from_f32(m, m, &dist).unwrap();

        let random: Vec<f32> = rng.normal_vec_f32(m * 2);
        let s_random = SmacofMds::stress(&random, 2, &delta);
        let out = SmacofMds::default().fit_transform(&data, 8, 2).unwrap();
        let s_fit = SmacofMds::stress(&out, 2, &delta);
        assert!(s_fit < s_random, "fit stress {s_fit} >= random stress {s_random}");
    }

    #[test]
    fn smacof_warm_started_recovers_planar_data() {
        let m = 12;
        let data = planar_in_6d(m, 9);
        let out = SmacofMds::warm_started().fit_transform(&data, 6, 2).unwrap();
        assert!(max_distortion(&data, 6, &out, 2, m) < 0.05);
    }

    #[test]
    fn smacof_random_init_worse_or_equal_to_warm_start() {
        // The sklearn-default behaviour the paper benchmarked: random init
        // lands in local minima, so its stress is ≥ the warm-started run.
        let mut rng = Rng::new(15);
        let m = 14;
        let data = rng.normal_vec_f32(m * 10);
        let dist = pairwise_distances_symmetric(&data, 10, Metric::Euclidean).unwrap();
        let delta = Mat::from_f32(m, m, &dist).unwrap();
        let cold = SmacofMds::default().fit_transform(&data, 10, 2).unwrap();
        let warm = SmacofMds::warm_started().fit_transform(&data, 10, 2).unwrap();
        let s_cold = SmacofMds::stress(&cold, 2, &delta);
        let s_warm = SmacofMds::stress(&warm, 2, &delta);
        assert!(s_warm <= s_cold * 1.05, "warm {s_warm} vs cold {s_cold}");
    }

    #[test]
    fn embed_rejects_bad_target() {
        let d = Mat::zeros(4, 4);
        assert!(ClassicalMds::new().embed_from_sq_distances(&d, 0).is_err());
        assert!(ClassicalMds::new().embed_from_sq_distances(&d, 5).is_err());
    }

    #[test]
    fn reducers_shape_checks() {
        let data = [0.0f32; 12];
        assert!(ClassicalMds::new().fit_transform(&data, 5, 2).is_err());
        assert!(SmacofMds::default().fit_transform(&data, 4, 5).is_err());
    }

    #[test]
    fn stress_of_perfect_embedding_is_zero() {
        let mut rng = Rng::new(10);
        let m = 8;
        let data = rng.normal_vec_f32(m * 3);
        let dist = pairwise_distances_symmetric(&data, 3, Metric::Euclidean).unwrap();
        let delta = Mat::from_f32(m, m, &dist).unwrap();
        assert!(SmacofMds::stress(&data, 3, &delta) < 1e-8);
    }
}
