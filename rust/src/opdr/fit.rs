//! Fitting the closed-form function (paper Eq. 4):
//!
//! ```text
//! A_k = c0 · log(n/m) + c1
//! ```
//!
//! where `n = dim(Y)` and `m = |Y|`. The paper estimates `c0, c1` "by various
//! regression models"; we provide ordinary least squares on the log ratio,
//! a Huber-robust variant (outlier-tolerant, matching the paper's noisier
//! web datasets), and the alternative functional forms used by the ablation
//! bench (linear and sqrt in n/m) so the log model's superiority is testable.

use crate::error::{OpdrError, Result};
use crate::util::float::mean;

/// A fitted `A = c0·log(n/m) + c1` model.
#[derive(Debug, Clone, Copy)]
pub struct LogFit {
    /// Slope against `ln(n/m)`.
    pub c0: f64,
    /// Intercept.
    pub c1: f64,
    /// Coefficient of determination on the training points.
    pub r_squared: f64,
    /// Number of points fitted.
    pub n_points: usize,
}

impl LogFit {
    /// Predicted accuracy for a ratio `n/m`, clamped to [0, 1].
    pub fn predict(&self, ratio: f64) -> f64 {
        if ratio <= 0.0 {
            return 0.0;
        }
        (self.c0 * ratio.ln() + self.c1).clamp(0.0, 1.0)
    }

    /// Raw (unclamped) prediction — used by the planner's inversion.
    pub fn predict_raw(&self, ratio: f64) -> f64 {
        self.c0 * ratio.ln() + self.c1
    }
}

/// Ordinary least squares of `a = c0·ln(r) + c1` over `(ratio, accuracy)`
/// points. Ratios must be positive; accuracies in [0, 1].
pub fn fit_log_model(points: &[(f64, f64)]) -> Result<LogFit> {
    fit_transformed(points, f64::ln)
}

/// OLS of `a = c0·r + c1` (ablation alternative).
pub fn fit_linear_model(points: &[(f64, f64)]) -> Result<LogFit> {
    fit_transformed(points, |r| r)
}

/// OLS of `a = c0·sqrt(r) + c1` (ablation alternative).
pub fn fit_sqrt_model(points: &[(f64, f64)]) -> Result<LogFit> {
    fit_transformed(points, f64::sqrt)
}

fn fit_transformed(points: &[(f64, f64)], xform: impl Fn(f64) -> f64) -> Result<LogFit> {
    if points.len() < 2 {
        return Err(OpdrError::numeric("fit: need at least 2 points"));
    }
    for &(r, a) in points {
        if r <= 0.0 || !r.is_finite() {
            return Err(OpdrError::numeric(format!("fit: ratio {r} not positive/finite")));
        }
        if !(0.0..=1.0).contains(&a) {
            return Err(OpdrError::numeric(format!("fit: accuracy {a} outside [0,1]")));
        }
    }
    let xs: Vec<f64> = points.iter().map(|&(r, _)| xform(r)).collect();
    let ys: Vec<f64> = points.iter().map(|&(_, a)| a).collect();
    let mx = mean(&xs);
    let my = mean(&ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx < 1e-12 {
        return Err(OpdrError::numeric("fit: ratios are all identical"));
    }
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let c0 = sxy / sxx;
    let c1 = my - c0 * mx;

    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| {
            let pred = c0 * x + c1;
            (y - pred) * (y - pred)
        })
        .sum();
    let r_squared = if ss_tot < 1e-15 { 1.0 } else { 1.0 - ss_res / ss_tot };

    Ok(LogFit { c0, c1, r_squared, n_points: points.len() })
}

/// Huber-robust fit of the log model via iteratively reweighted least squares.
///
/// `delta` is the Huber threshold on residuals (≈1.35σ is classic; accuracy
/// residuals live in [−1,1] so 0.05–0.1 is a sensible range here).
pub fn fit_log_model_huber(points: &[(f64, f64)], delta: f64, iters: usize) -> Result<LogFit> {
    let mut fit = fit_log_model(points)?;
    if delta <= 0.0 {
        return Err(OpdrError::numeric("huber: delta must be positive"));
    }
    let xs: Vec<f64> = points.iter().map(|&(r, _)| r.ln()).collect();
    let ys: Vec<f64> = points.iter().map(|&(_, a)| a).collect();

    for _ in 0..iters {
        // Weights from current residuals.
        let w: Vec<f64> = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| {
                let r = (y - (fit.c0 * x + fit.c1)).abs();
                if r <= delta {
                    1.0
                } else {
                    delta / r
                }
            })
            .collect();
        // Weighted least squares.
        let sw: f64 = w.iter().sum();
        let mx: f64 = xs.iter().zip(&w).map(|(x, wi)| x * wi).sum::<f64>() / sw;
        let my: f64 = ys.iter().zip(&w).map(|(y, wi)| y * wi).sum::<f64>() / sw;
        let sxx: f64 = xs.iter().zip(&w).map(|(x, wi)| wi * (x - mx) * (x - mx)).sum();
        if sxx < 1e-12 {
            break;
        }
        let sxy: f64 = xs
            .iter()
            .zip(ys.iter().zip(&w))
            .map(|(x, (y, wi))| wi * (x - mx) * (y - my))
            .sum();
        let c0 = sxy / sxx;
        let c1 = my - c0 * mx;
        if (c0 - fit.c0).abs() < 1e-12 && (c1 - fit.c1).abs() < 1e-12 {
            fit.c0 = c0;
            fit.c1 = c1;
            break;
        }
        fit.c0 = c0;
        fit.c1 = c1;
    }

    // Recompute unweighted R² for comparability.
    let my = mean(&ys);
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| {
            let p = fit.c0 * x + fit.c1;
            (y - p) * (y - p)
        })
        .sum();
    fit.r_squared = if ss_tot < 1e-15 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Ok(fit)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn synthetic_points(c0: f64, c1: f64, noise: f64, seed: u64, n: usize) -> Vec<(f64, f64)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let ratio = 0.05 + 0.95 * (i as f64 / (n - 1) as f64);
                let a = (c0 * ratio.ln() + c1 + noise * rng.normal()).clamp(0.0, 1.0);
                (ratio, a)
            })
            .collect()
    }

    #[test]
    fn recovers_exact_coefficients() {
        let pts = synthetic_points(0.2, 0.9, 0.0, 1, 20);
        let fit = fit_log_model(&pts).unwrap();
        assert!((fit.c0 - 0.2).abs() < 1e-9);
        assert!((fit.c1 - 0.9).abs() < 1e-9);
        assert!(fit.r_squared > 0.999);
    }

    #[test]
    fn noisy_fit_close_and_r2_reasonable() {
        let pts = synthetic_points(0.15, 0.85, 0.02, 2, 50);
        let fit = fit_log_model(&pts).unwrap();
        assert!((fit.c0 - 0.15).abs() < 0.03, "c0={}", fit.c0);
        assert!(fit.r_squared > 0.8, "r2={}", fit.r_squared);
    }

    #[test]
    fn predict_clamps() {
        let fit = LogFit { c0: 0.5, c1: 0.9, r_squared: 1.0, n_points: 2 };
        assert_eq!(fit.predict(1e9), 1.0);
        assert_eq!(fit.predict(1e-9), 0.0);
        assert_eq!(fit.predict(0.0), 0.0);
    }

    #[test]
    fn validation_rejects_bad_input() {
        assert!(fit_log_model(&[(1.0, 0.5)]).is_err()); // too few
        assert!(fit_log_model(&[(0.0, 0.5), (1.0, 0.6)]).is_err()); // ratio 0
        assert!(fit_log_model(&[(0.5, 1.5), (1.0, 0.6)]).is_err()); // accuracy > 1
        assert!(fit_log_model(&[(0.5, 0.5), (0.5, 0.6)]).is_err()); // identical ratios
    }

    #[test]
    fn huber_resists_outliers() {
        let mut pts = synthetic_points(0.2, 0.9, 0.0, 3, 30);
        // Corrupt two points hard.
        pts[3].1 = 0.0;
        pts[20].1 = 0.0;
        let ols = fit_log_model(&pts).unwrap();
        let rob = fit_log_model_huber(&pts, 0.05, 30).unwrap();
        assert!(
            (rob.c0 - 0.2).abs() < (ols.c0 - 0.2).abs(),
            "huber {} should beat ols {}",
            rob.c0,
            ols.c0
        );
    }

    #[test]
    fn log_model_beats_linear_on_log_data() {
        // Data generated from the paper's log form: the log fit must hold a
        // higher R² than a linear-in-ratio fit.
        let pts = synthetic_points(0.18, 0.88, 0.01, 4, 40);
        let log_fit = fit_log_model(&pts).unwrap();
        let lin_fit = fit_linear_model(&pts).unwrap();
        assert!(log_fit.r_squared > lin_fit.r_squared);
    }

    #[test]
    fn alternative_models_fit_cleanly() {
        let pts: Vec<(f64, f64)> =
            (1..20).map(|i| (i as f64 / 20.0, (0.3 * (i as f64 / 20.0) + 0.5).min(1.0))).collect();
        assert!(fit_linear_model(&pts).unwrap().r_squared > 0.999);
        assert!(fit_sqrt_model(&pts).unwrap().r_squared > 0.9);
    }
}
