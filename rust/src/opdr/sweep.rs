//! Accuracy-vs-n/m sweep engine — the machinery behind every paper figure.
//!
//! A sweep takes an embedding set, draws subsets of the paper's sizes
//! (m ∈ {10..80} for the materials datasets, {10..300} for the web corpora),
//! reduces each subset to a log-spaced range of target dims `n`, and records
//! the order-preserving accuracy at each `(n/m, A_k)` point.

use crate::data::EmbeddingSet;
use crate::error::Result;
use crate::metrics::Metric;
use crate::opdr::planner::accuracy_curve_over;
use crate::reduction::ReducerKind;

/// Configuration of one sweep (raw-data level; dataset selection lives in
/// [`crate::config::SweepSpec`]).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Neighborhood size `k`.
    pub k: usize,
    /// Distance metric.
    pub metric: Metric,
    /// Reduction method.
    pub reducer: ReducerKind,
    /// Subset sizes `m`.
    pub sample_sizes: Vec<usize>,
    /// Target dims per subset (log-spaced in `[1, min(d, m)]`).
    pub dims_per_m: usize,
    /// Repetitions per cell (different random subsets), averaged by callers.
    pub repeats: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            k: 5,
            metric: Metric::SqEuclidean,
            reducer: ReducerKind::Pca,
            sample_sizes: vec![10, 20, 30, 40, 50, 60, 70, 80],
            dims_per_m: 12,
            repeats: 3,
            seed: 42,
        }
    }
}

/// The result of a sweep: raw `(n/m, A_k)` scatter plus labels.
#[derive(Debug, Clone)]
pub struct AccuracyCurve {
    /// Raw scatter points `(ratio, accuracy)`.
    raw: Vec<(f64, f64)>,
    /// Label of the dataset / configuration that produced the curve.
    pub label: String,
}

impl AccuracyCurve {
    /// Construct from raw points.
    pub fn new(label: impl Into<String>, raw: Vec<(f64, f64)>) -> Self {
        AccuracyCurve { raw, label: label.into() }
    }

    /// Raw `(ratio, accuracy)` points.
    pub fn points(&self) -> &[(f64, f64)] {
        &self.raw
    }

    /// Points averaged into `bins` equal-width bins over `log(ratio)` — the
    /// smoothed series the paper plots.
    pub fn binned(&self, bins: usize) -> Vec<(f64, f64)> {
        if self.raw.is_empty() || bins == 0 {
            return vec![];
        }
        let logs: Vec<f64> = self.raw.iter().map(|&(r, _)| r.ln()).collect();
        let lo = logs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if !(hi > lo) {
            // Single ratio value: average everything.
            let mean_a: f64 =
                self.raw.iter().map(|&(_, a)| a).sum::<f64>() / self.raw.len() as f64;
            return vec![(self.raw[0].0, mean_a)];
        }
        let width = (hi - lo) / bins as f64;
        let mut sums = vec![(0.0f64, 0.0f64, 0usize); bins];
        for (&(r, a), &lg) in self.raw.iter().zip(&logs) {
            let mut b = ((lg - lo) / width) as usize;
            if b >= bins {
                b = bins - 1;
            }
            sums[b].0 += r;
            sums[b].1 += a;
            sums[b].2 += 1;
        }
        sums.into_iter()
            .filter(|&(_, _, n)| n > 0)
            .map(|(r, a, n)| (r / n as f64, a / n as f64))
            .collect()
    }

    /// Convergence value: mean accuracy over the top decile of ratios.
    /// NaN ratios (a degenerate sweep cell) sort last via the IEEE total
    /// order instead of panicking the whole report.
    pub fn plateau_accuracy(&self) -> f64 {
        if self.raw.is_empty() {
            return 0.0;
        }
        let mut sorted = self.raw.clone();
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
        let start = sorted.len() * 9 / 10;
        let tail = &sorted[start..];
        tail.iter().map(|&(_, a)| a).sum::<f64>() / tail.len() as f64
    }
}

/// Run a sweep over an [`EmbeddingSet`].
pub fn accuracy_curve(set: &EmbeddingSet, cfg: &SweepConfig) -> Result<AccuracyCurve> {
    let pts = accuracy_curve_over(set.data(), set.dim(), &cfg.sample_sizes, &sweep_to_raw(cfg))?;
    Ok(AccuracyCurve::new(set.label().to_string(), pts))
}

// accuracy_curve_over takes the same struct; helper to keep a single source of
// truth if the types ever diverge.
fn sweep_to_raw(cfg: &SweepConfig) -> SweepConfig {
    cfg.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DatasetKind};

    #[test]
    fn sweep_on_materials_shows_log_trend() {
        let set = synth::generate(DatasetKind::MaterialsObservable, 40, 64, 7);
        let cfg = SweepConfig {
            sample_sizes: vec![20, 40],
            dims_per_m: 8,
            repeats: 2,
            ..Default::default()
        };
        let curve = accuracy_curve(&set, &cfg).unwrap();
        assert!(!curve.points().is_empty());
        // All accuracies in range.
        for &(r, a) in curve.points() {
            assert!(r > 0.0 && r <= 1.0 + 1e-9, "ratio {r}");
            assert!((0.0..=1.0).contains(&a));
        }
        // Low-ratio accuracy below high-ratio accuracy (the paper's trend).
        let binned = curve.binned(4);
        assert!(binned.len() >= 2);
        assert!(
            binned.last().unwrap().1 > binned.first().unwrap().1,
            "no positive trend: {binned:?}"
        );
        // Plateau should be decent for PCA on structured data.
        assert!(curve.plateau_accuracy() > 0.7, "plateau {}", curve.plateau_accuracy());
    }

    #[test]
    fn binned_handles_degenerate_input() {
        let c = AccuracyCurve::new("x", vec![]);
        assert!(c.binned(4).is_empty());
        let c = AccuracyCurve::new("x", vec![(0.5, 0.8), (0.5, 0.6)]);
        let b = c.binned(4);
        assert_eq!(b.len(), 1);
        assert!((b[0].1 - 0.7).abs() < 1e-12);
    }

    #[test]
    fn plateau_of_empty_curve() {
        assert_eq!(AccuracyCurve::new("x", vec![]).plateau_accuracy(), 0.0);
    }

    #[test]
    fn plateau_tolerates_nan_ratios() {
        // Regression: `partial_cmp(..).unwrap()` here used to panic on any
        // NaN ratio, taking the whole report down with it. NaN ratios sort
        // last (IEEE total order) and only dilute the top decile.
        let c = AccuracyCurve::new(
            "x",
            vec![(0.1, 0.2), (0.5, 0.5), (f64::NAN, 0.9), (1.0, 0.8)],
        );
        let p = c.plateau_accuracy();
        // The NaN ratio sorts last, so the top decile is exactly that point.
        assert!((p - 0.9).abs() < 1e-12, "plateau {p}");
    }
}
