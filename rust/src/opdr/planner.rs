//! The dimensionality planner `g`: inverting the closed-form function.
//!
//! The paper's practical recipe is the composition `f ∘ g`: from a target
//! accuracy `A_target` and a cardinality `m`, compute
//!
//! ```text
//! dim(Y) = g(A_target, m) = m · exp((A_target − c1) / c0)
//! ```
//!
//! and hand that dimension to the reduction method `f`. The planner owns a
//! fitted [`LogFit`] (obtained either from a calibration sweep on a sample of
//! the user's data, or from a stored config) and performs the inversion with
//! the necessary clamping (1 ≤ dim(Y) ≤ original dim, dim(Y) ≤ m for
//! sample-bounded reducers).

use crate::error::{OpdrError, Result};
use crate::metrics::Metric;
use crate::opdr::fit::{fit_log_model, LogFit};
use crate::opdr::sweep::SweepConfig;
use crate::reduction::ReducerKind;

/// Plans target dimensionalities from a fitted closed-form model.
#[derive(Debug, Clone, Copy)]
pub struct Planner {
    fit: LogFit,
}

impl Planner {
    /// Wrap an existing fit.
    pub fn from_fit(fit: LogFit) -> Self {
        Planner { fit }
    }

    /// Calibrate by running an accuracy sweep on (a sample of) the user's own
    /// embeddings, then fitting Eq. (4). This is the paper's intended usage:
    /// the constants c0/c1 are dataset- and method-specific.
    pub fn calibrate(
        data: &[f32],
        dim: usize,
        k: usize,
        metric: Metric,
        reducer: ReducerKind,
        seed: u64,
    ) -> Result<Self> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("planner: bad data shape"));
        }
        let m = data.len() / dim;
        if m <= k + 1 {
            return Err(OpdrError::shape("planner: need more samples than k+1"));
        }
        let cfg = SweepConfig {
            k,
            metric,
            reducer,
            seed,
            dims_per_m: 10,
            repeats: 1,
            ..Default::default()
        };
        let curve = accuracy_curve_from_raw(data, dim, m, &cfg)?;
        let fit = fit_log_model(&curve)?;
        Ok(Planner { fit })
    }

    /// The underlying fit.
    pub fn fit(&self) -> LogFit {
        self.fit
    }

    /// `g(A_target, m)` — the minimum dimension predicted to reach
    /// `target_accuracy` with `m` points. Clamped to `[1, m]` (the reducers
    /// here can produce at most `m` informative dimensions; callers should
    /// additionally clamp to the original dimensionality).
    pub fn dim_for_accuracy(&self, target_accuracy: f64, m: usize) -> usize {
        let a = target_accuracy.clamp(0.0, 1.0);
        if self.fit.c0.abs() < 1e-12 {
            // Flat fit: accuracy does not depend on dim; be conservative.
            return m.max(1);
        }
        let ratio = ((a - self.fit.c1) / self.fit.c0).exp();
        let dim = (ratio * m as f64).ceil();
        (dim as usize).clamp(1, m.max(1))
    }

    /// Predicted accuracy at `(n, m)` — the forward direction of Eq. (4).
    pub fn predicted_accuracy(&self, n: usize, m: usize) -> f64 {
        if m == 0 {
            return 0.0;
        }
        self.fit.predict(n as f64 / m as f64)
    }
}

/// Run a sweep over the *given* raw embedding block (no dataset generation)
/// and return (ratio, accuracy) points. Used by `Planner::calibrate`.
pub fn accuracy_curve_from_raw(
    data: &[f32],
    dim: usize,
    m: usize,
    cfg: &SweepConfig,
) -> Result<Vec<(f64, f64)>> {
    let curve = accuracy_curve_over(data, dim, &[m.min(data.len() / dim)], cfg)?;
    Ok(curve)
}

/// Sweep accuracy over explicit subset sizes of a raw embedding block.
pub fn accuracy_curve_over(
    data: &[f32],
    dim: usize,
    sample_sizes: &[usize],
    cfg: &SweepConfig,
) -> Result<Vec<(f64, f64)>> {
    let total = data.len() / dim;
    let mut points = Vec::new();
    let mut rng = crate::util::Rng::new(cfg.seed);
    for &m in sample_sizes {
        if m > total {
            return Err(OpdrError::data(format!("sweep: m={m} exceeds available {total}")));
        }
        if m <= cfg.k {
            return Err(OpdrError::config(format!("sweep: m={m} <= k={}", cfg.k)));
        }
        for rep in 0..cfg.repeats {
            // Random subset of m points.
            let idx = rng.sample_indices(total, m);
            let mut subset = Vec::with_capacity(m * dim);
            for &i in &idx {
                subset.extend_from_slice(&data[i * dim..(i + 1) * dim]);
            }
            // Log-spaced target dims in [1, min(dim, m)].
            let max_n = dim.min(m);
            let dims = log_spaced_dims(max_n, cfg.dims_per_m);
            let reducer = cfg.reducer.build(cfg.seed ^ (rep as u64) << 8);
            for n in dims {
                let reduced = reducer.fit_transform(&subset, dim, n)?;
                let a = crate::opdr::accuracy(&subset, dim, &reduced, n, cfg.k, cfg.metric)?;
                points.push((n as f64 / m as f64, a));
            }
        }
    }
    Ok(points)
}

/// Log-spaced integer dims in `[1, max_n]`, deduplicated, ascending.
pub fn log_spaced_dims(max_n: usize, count: usize) -> Vec<usize> {
    if max_n == 0 {
        return vec![];
    }
    let count = count.max(2);
    let mut dims: Vec<usize> = (0..count)
        .map(|i| {
            let t = i as f64 / (count - 1) as f64;
            let v = (max_n as f64).powf(t);
            v.round().clamp(1.0, max_n as f64) as usize
        })
        .collect();
    dims.sort_unstable();
    dims.dedup();
    dims
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opdr::fit::LogFit;
    use crate::util::Rng;

    fn fit(c0: f64, c1: f64) -> LogFit {
        LogFit { c0, c1, r_squared: 1.0, n_points: 10 }
    }

    #[test]
    fn inversion_roundtrip() {
        let p = Planner::from_fit(fit(0.2, 0.9));
        let m = 100;
        for target in [0.5, 0.7, 0.85] {
            let n = p.dim_for_accuracy(target, m);
            let pred = p.predicted_accuracy(n, m);
            assert!(pred >= target - 0.02, "target {target}: n={n}, pred={pred}");
        }
    }

    #[test]
    fn planner_monotone_in_target() {
        let p = Planner::from_fit(fit(0.15, 0.8));
        let m = 200;
        let mut prev = 0;
        for t in [0.2, 0.4, 0.6, 0.8, 0.95] {
            let n = p.dim_for_accuracy(t, m);
            assert!(n >= prev, "target {t}: {n} < {prev}");
            prev = n;
        }
    }

    #[test]
    fn planner_clamps_to_valid_range() {
        let p = Planner::from_fit(fit(0.2, 0.9));
        assert_eq!(p.dim_for_accuracy(2.0, 50), 50); // impossible target → all dims (A clamped to 1)
        assert!(p.dim_for_accuracy(0.0, 50) >= 1);
        let flat = Planner::from_fit(fit(0.0, 0.5));
        assert_eq!(flat.dim_for_accuracy(0.9, 64), 64); // conservative on flat fits
    }

    #[test]
    fn higher_cardinality_needs_more_dims() {
        // The paper's first observation: dim(Y) grows with m at fixed accuracy.
        let p = Planner::from_fit(fit(0.2, 0.85));
        let n_small = p.dim_for_accuracy(0.8, 50);
        let n_large = p.dim_for_accuracy(0.8, 500);
        assert!(n_large > n_small);
        // And the ratio n/m is invariant (the closed form depends on n/m only).
        let r_small = n_small as f64 / 50.0;
        let r_large = n_large as f64 / 500.0;
        assert!((r_small - r_large).abs() < 0.05);
    }

    #[test]
    fn log_spaced_dims_properties() {
        let dims = log_spaced_dims(64, 8);
        assert_eq!(*dims.first().unwrap(), 1);
        assert_eq!(*dims.last().unwrap(), 64);
        for w in dims.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(log_spaced_dims(0, 5).is_empty());
        assert_eq!(log_spaced_dims(1, 5), vec![1]);
    }

    #[test]
    fn calibrate_on_structured_data_predicts_usably() {
        // Structured low-rank data: calibration should produce a fit whose
        // planned dim actually achieves near the target accuracy.
        let mut rng = Rng::new(77);
        let m = 60;
        let dim = 48;
        let rank = 6;
        // low-rank + noise
        let basis: Vec<f32> = rng.normal_vec_f32(rank * dim);
        let mut data = vec![0.0f32; m * dim];
        for i in 0..m {
            let coefs: Vec<f32> = rng.normal_vec_f32(rank);
            for r in 0..rank {
                for j in 0..dim {
                    data[i * dim + j] += coefs[r] * basis[r * dim + j];
                }
            }
            for j in 0..dim {
                data[i * dim + j] += 0.05 * rng.normal() as f32;
            }
        }
        let planner =
            Planner::calibrate(&data, dim, 5, Metric::SqEuclidean, ReducerKind::Pca, 3).unwrap();
        let n = planner.dim_for_accuracy(0.8, m);
        assert!(n >= 1 && n <= m);
        // Measure the real accuracy at the planned dim.
        let reduced = ReducerKind::Pca.build(0).fit_transform(&data, dim, n.min(dim)).unwrap();
        let a = crate::opdr::accuracy(&data, dim, &reduced, n.min(dim), 5, Metric::SqEuclidean).unwrap();
        assert!(a > 0.6, "planned n={n} gave accuracy {a}");
    }

    #[test]
    fn sweep_over_raw_rejects_bad_m() {
        let data = vec![0.0f32; 20 * 4];
        let cfg = SweepConfig::default();
        assert!(accuracy_curve_over(&data, 4, &[100], &cfg).is_err());
        assert!(accuracy_curve_over(&data, 4, &[3], &cfg).is_err()); // m <= k
    }
}
