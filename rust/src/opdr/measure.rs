//! The order-preserving measure `μ` (paper Eq. 1).
//!
//! For a point `y_i` in the reduced space `Y`, let `E_{k,i}^Y` be its set of
//! k-nearest neighbors in `Y` and `E_{k,i}^X` the k-nearest neighbors of its
//! pre-image in the original space `X`. For any `F ∈ P(Y)` (the power-set
//! σ-algebra), the paper defines
//!
//! ```text
//! μ_i(F) = |F ∩ E_{k,i}^Y ∩ E_{k,i}^X| / k
//! ```
//!
//! which is a measure: μ(∅)=0 and μ is finitely additive over disjoint sets
//! (verified by the property tests below — this is the paper's central
//! formal object, so we test its *axioms*, not just values).
//!
//! Sets are represented by sorted `usize` point indices; `F` is any subset of
//! indices of `Y`.

use crate::error::{OpdrError, Result};
use crate::knn::knn_indices_all;
use crate::metrics::Metric;
use std::collections::HashSet;

/// Precomputed leave-one-out k-NN sets for the original space `X` and the
/// reduced space `Y` over the same point set.
#[derive(Debug, Clone)]
pub struct NeighborSets {
    /// Neighborhood size.
    pub k: usize,
    /// `E_{k,i}^X` per point.
    pub in_x: Vec<Vec<usize>>,
    /// `E_{k,i}^Y` per point.
    pub in_y: Vec<Vec<usize>>,
}

impl NeighborSets {
    /// Compute exact neighbor sets in both spaces.
    ///
    /// `x` is `m×dim_x` row-major, `y` is `m×dim_y`; the point at row `i` of
    /// `y` must be the image of row `i` of `x` (the dimension-reduction map
    /// is index-aligned by construction).
    pub fn compute(
        x: &[f32],
        dim_x: usize,
        y: &[f32],
        dim_y: usize,
        k: usize,
        metric: Metric,
    ) -> Result<Self> {
        if dim_x == 0 || dim_y == 0 || x.len() % dim_x != 0 || y.len() % dim_y != 0 {
            return Err(OpdrError::shape("NeighborSets: bad shapes"));
        }
        let m = x.len() / dim_x;
        if y.len() / dim_y != m {
            return Err(OpdrError::shape("NeighborSets: X and Y cardinality differ"));
        }
        if k == 0 {
            return Err(OpdrError::shape("NeighborSets: k must be >= 1"));
        }
        if k >= m {
            return Err(OpdrError::shape(format!("NeighborSets: k={k} >= m={m}")));
        }
        let in_x = knn_indices_all(x, dim_x, k, metric)?;
        let in_y = knn_indices_all(y, dim_y, k, metric)?;
        Ok(NeighborSets { k, in_x, in_y })
    }

    /// Number of points `m`.
    pub fn len(&self) -> usize {
        self.in_x.len()
    }

    /// True when there are no points.
    pub fn is_empty(&self) -> bool {
        self.in_x.is_empty()
    }

    /// `E_{k,i}^Y ∩ E_{k,i}^X` as a hash set (the `E` of the paper's proof).
    pub fn preserved_set(&self, i: usize) -> HashSet<usize> {
        let sx: HashSet<usize> = self.in_x[i].iter().copied().collect();
        self.in_y[i].iter().copied().filter(|j| sx.contains(j)).collect()
    }
}

/// `|E_{k,i}^Y ∩ E_{k,i}^X|` — the number of preserved neighbors of point `i`.
pub fn preserved_count(sets: &NeighborSets, i: usize) -> usize {
    sets.preserved_set(i).len()
}

/// The measure `μ_i(F)` of Eq. (1): `|F ∩ E_{k,i}^Y ∩ E_{k,i}^X| / k`.
///
/// `f` is a subset of point indices of `Y` (an element of the power-set
/// σ-algebra `M_Y = P(Y)`).
pub fn op_measure(sets: &NeighborSets, i: usize, f: &[usize]) -> f64 {
    let e = sets.preserved_set(i);
    let hits = f.iter().filter(|j| e.contains(j)).count();
    hits as f64 / sets.k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_sets() -> NeighborSets {
        // 6 colinear points; k = 2. Identity "reduction" (Y = X) means all
        // neighbors preserved.
        let x = [0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0];
        NeighborSets::compute(&x, 1, &x, 1, 2, Metric::Euclidean).unwrap()
    }

    #[test]
    fn identity_map_preserves_everything() {
        let s = toy_sets();
        for i in 0..s.len() {
            assert_eq!(preserved_count(&s, i), 2);
        }
    }

    #[test]
    fn measure_of_empty_set_is_zero() {
        // Measure axiom (i): μ(∅) = 0.
        let s = toy_sets();
        for i in 0..s.len() {
            assert_eq!(op_measure(&s, i, &[]), 0.0);
        }
    }

    #[test]
    fn measure_additive_on_disjoint_sets() {
        // Measure axiom (ii): μ(F1 ∪ F2) = μ(F1) + μ(F2) for disjoint F1, F2.
        let s = toy_sets();
        let i = 2; // neighbors of point 2 are {1, 3}
        let f1 = vec![1usize];
        let f2 = vec![3usize, 4];
        let union: Vec<usize> = f1.iter().chain(f2.iter()).copied().collect();
        let lhs = op_measure(&s, i, &union);
        let rhs = op_measure(&s, i, &f1) + op_measure(&s, i, &f2);
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn measure_additivity_random_partitions() {
        // Property test: additivity over random disjoint partitions of Y.
        let mut rng = Rng::new(40);
        let m = 20;
        let dim = 5;
        let x = rng.normal_vec_f32(m * dim);
        let y = rng.normal_vec_f32(m * 2); // arbitrary "reduction"
        let s = NeighborSets::compute(&x, dim, &y, 2, 4, Metric::Euclidean).unwrap();
        for trial in 0..50 {
            let i = rng.below(m);
            // Random partition of indices into two disjoint sets.
            let mut f1 = Vec::new();
            let mut f2 = Vec::new();
            for j in 0..m {
                if rng.uniform() < 0.5 {
                    f1.push(j);
                } else {
                    f2.push(j);
                }
            }
            let union: Vec<usize> = f1.iter().chain(f2.iter()).copied().collect();
            let lhs = op_measure(&s, i, &union);
            let rhs = op_measure(&s, i, &f1) + op_measure(&s, i, &f2);
            assert!((lhs - rhs).abs() < 1e-12, "trial {trial}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn measure_bounded_by_one() {
        let mut rng = Rng::new(41);
        let m = 15;
        let x = rng.normal_vec_f32(m * 4);
        let y = rng.normal_vec_f32(m * 2);
        let s = NeighborSets::compute(&x, 4, &y, 2, 3, Metric::Euclidean).unwrap();
        let all: Vec<usize> = (0..m).collect();
        for i in 0..m {
            let mu = op_measure(&s, i, &all);
            assert!((0.0..=1.0).contains(&mu));
        }
    }

    #[test]
    fn monotone_under_inclusion() {
        // F ⊆ G ⇒ μ(F) ≤ μ(G) — follows from additivity + non-negativity.
        let mut rng = Rng::new(42);
        let m = 12;
        let x = rng.normal_vec_f32(m * 4);
        let y = rng.normal_vec_f32(m * 2);
        let s = NeighborSets::compute(&x, 4, &y, 2, 3, Metric::Euclidean).unwrap();
        let f: Vec<usize> = (0..6).collect();
        let g: Vec<usize> = (0..12).collect();
        for i in 0..m {
            assert!(op_measure(&s, i, &f) <= op_measure(&s, i, &g) + 1e-12);
        }
    }

    #[test]
    fn shape_and_k_validation() {
        let x = [0.0f32; 8];
        let y = [0.0f32; 4];
        assert!(NeighborSets::compute(&x, 2, &y, 1, 0, Metric::Euclidean).is_err()); // k=0
        assert!(NeighborSets::compute(&x, 2, &y, 1, 4, Metric::Euclidean).is_err()); // k>=m
        assert!(NeighborSets::compute(&x, 3, &y, 1, 1, Metric::Euclidean).is_err()); // ragged
        assert!(NeighborSets::compute(&x, 2, &y, 3, 1, Metric::Euclidean).is_err()); // |X| != |Y|
    }

    #[test]
    fn op2_not_op1_example_from_paper() {
        // The paper's example: L_X = (a, b, c), L_Y = (b, a, c): OP_2 holds
        // ({a,b} = {b,a}) but OP_1 fails ({a} != {b}).
        //
        // Realize it with distances from a query point q = index 0:
        // X: d(q,a)=1, d(q,b)=2, d(q,c)=3 ; Y: d(q,b)=1, d(q,a)=2, d(q,c)=3.
        let x = [0.0f32, 1.0, 2.0, 3.0]; // q, a, b, c on a line
        let y = [0.0f32, 2.0, 1.0, 3.0]; // a and b swapped
        let s2 = NeighborSets::compute(&x, 1, &y, 1, 2, Metric::Euclidean).unwrap();
        assert_eq!(preserved_count(&s2, 0), 2, "OP_2 must hold");
        let s1 = NeighborSets::compute(&x, 1, &y, 1, 1, Metric::Euclidean).unwrap();
        assert_eq!(preserved_count(&s1, 0), 0, "OP_1 must fail");
    }
}
