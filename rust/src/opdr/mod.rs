//! The paper's contribution: the order-preserving measure, the global
//! accuracy metric, the closed-form fit, and the dimensionality planner.
//!
//! * [`measure`] — the set measure `μ` of Eq. (1) on the power-set σ-algebra
//!   of the reduced space;
//! * [`accuracy`] — the global accuracy `A_k^X(Y)` of Eq. (2);
//! * [`fit`] — least-squares (and Huber-robust) fitting of the closed form
//!   `A_k = c0·log(n/m) + c1` of Eq. (4);
//! * [`planner`] — inversion of the fit into `dim(Y) = g(A_target, m)`;
//! * [`sweep`] — accuracy-vs-n/m curve generation (the engine behind every
//!   figure bench).

pub mod accuracy;
pub mod fit;
pub mod measure;
pub mod planner;
pub mod sweep;

pub use accuracy::{accuracy, accuracy_from_sets};
pub use fit::{fit_log_model, LogFit};
pub use measure::{op_measure, preserved_count, NeighborSets};
pub use planner::Planner;
pub use sweep::{accuracy_curve, AccuracyCurve, SweepConfig};
