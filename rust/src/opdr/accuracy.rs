//! The global accuracy `A_k^X(Y)` (paper Eq. 2).
//!
//! ```text
//! A_k^X(Y) = (1/m) Σ_i μ_i(Y \ {y_i}) / k  ... with μ_i already 1/k-scaled,
//! ```
//!
//! i.e. the mean over all points of the fraction of their k nearest neighbors
//! that survive the reduction. `A ∈ [0, 1]`; `A = 1` means the map is `OP_k`.

use crate::error::Result;
use crate::metrics::Metric;
use crate::opdr::measure::NeighborSets;

/// Accuracy from precomputed neighbor sets.
pub fn accuracy_from_sets(sets: &NeighborSets) -> f64 {
    if sets.is_empty() {
        return 1.0; // vacuous: nothing to preserve
    }
    let m = sets.len();
    let total: f64 = (0..m)
        .map(|i| sets.preserved_set(i).len() as f64 / sets.k as f64)
        .sum();
    total / m as f64
}

/// End-to-end accuracy: compute neighbor sets in `X` and `Y` and average the
/// per-point measures. This is the quantity every figure of the paper plots.
pub fn accuracy(
    x: &[f32],
    dim_x: usize,
    y: &[f32],
    dim_y: usize,
    k: usize,
    metric: Metric,
) -> Result<f64> {
    let sets = NeighborSets::compute(x, dim_x, y, dim_y, k, metric)?;
    Ok(accuracy_from_sets(&sets))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reduction::{DimReducer, Pca};
    use crate::util::Rng;

    #[test]
    fn identity_reduction_scores_one() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec_f32(30 * 8);
        let a = accuracy(&x, 8, &x, 8, 5, Metric::Euclidean).unwrap();
        assert_eq!(a, 1.0);
    }

    #[test]
    fn random_unrelated_y_scores_low() {
        let mut rng = Rng::new(2);
        let m = 60;
        let x = rng.normal_vec_f32(m * 16);
        let y = rng.normal_vec_f32(m * 2); // unrelated coordinates
        let a = accuracy(&x, 16, &y, 2, 5, Metric::Euclidean).unwrap();
        // Expected preserved fraction for random sets ≈ k/(m-1) ≈ 0.085.
        assert!(a < 0.35, "a={a}");
    }

    #[test]
    fn accuracy_in_unit_interval() {
        let mut rng = Rng::new(3);
        for trial in 0..10 {
            let m = 10 + rng.below(30);
            let x = rng.normal_vec_f32(m * 8);
            let y = rng.normal_vec_f32(m * 3);
            let a = accuracy(&x, 8, &y, 3, 4, Metric::Euclidean).unwrap();
            assert!((0.0..=1.0).contains(&a), "trial {trial}: a={a}");
        }
    }

    #[test]
    fn rotation_is_op_k() {
        // Full-dim PCA is a rigid rotation: A_k must be exactly 1 (paper's
        // "if Y = X then A_k = 1.0" extreme case, generalized to isometries).
        let mut rng = Rng::new(4);
        let m = 25;
        let dim = 6;
        let x = rng.normal_vec_f32(m * dim);
        let y = Pca::new().fit_transform(&x, dim, dim).unwrap();
        let a = accuracy(&x, dim, &y, dim, 5, Metric::Euclidean).unwrap();
        assert_eq!(a, 1.0);
    }

    #[test]
    fn pca_accuracy_monotone_in_target_dim_on_average() {
        // More dimensions kept → (weakly) better neighbor preservation.
        let mut rng = Rng::new(5);
        let m = 40;
        let dim = 32;
        let x = rng.normal_vec_f32(m * dim);
        let mut prev = 0.0;
        let mut violations = 0;
        for target in [2usize, 8, 16, 32] {
            let y = Pca::new().fit_transform(&x, dim, target).unwrap();
            let a = accuracy(&x, dim, &y, target, 5, Metric::Euclidean).unwrap();
            if a + 0.05 < prev {
                violations += 1;
            }
            prev = a;
        }
        assert!(violations == 0, "accuracy dropped sharply as target_dim grew");
    }

    #[test]
    fn empty_sets_edge() {
        let s = NeighborSets { k: 3, in_x: vec![], in_y: vec![] };
        assert_eq!(accuracy_from_sets(&s), 1.0);
    }
}
