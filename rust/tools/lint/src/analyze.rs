//! The `opdr-lint analyze` concurrency pass.
//!
//! Where `rules.rs` checks *local* syntactic invariants, this module builds
//! a small cross-file model of the tree's locking behaviour from the same
//! token streams and checks *global* ones:
//!
//! - **`lock-order`** — track `lock_recover(..)` / `lock_recover_ranked(..)`
//!   guard bindings and their brace-scoped lifetimes per function, resolve
//!   each lock to a named site (ranked sites come from the rank table in
//!   `util/sync.rs`; plain `lock_recover` sites are named after the guarded
//!   field, prefixed by the file stem), propagate acquisitions through
//!   direct calls with an interprocedural fixpoint, and fail on any cycle
//!   in the acquired-while-holding graph (`A -> B -> A`).
//! - **`rank-table-sync`** — both directions, like `metric-docs-sync`:
//!   every rank constant declared in `util/sync.rs` must be used at some
//!   `lock_recover_ranked` call site, every ranked call site must name a
//!   declared constant, the table must have unique names and ranks, and
//!   every statically observed edge between two *ranked* sites must go
//!   from a lower rank to a strictly higher one — so the static graph and
//!   the runtime sentinel can never drift apart.
//! - **`atomic-ordering`** — every `Ordering::Relaxed` needs an
//!   `// ORDERING:` justification comment within the 6 preceding lines
//!   (same shape as `unsafe-needs-safety-comment`): Relaxed is correct for
//!   monotonic counters and advisory flags, but silently wrong for
//!   cross-thread publication, so the claim must be written down.
//! - **`unbounded-channel`** — `std::sync::mpsc::channel()` on the serving
//!   and build paths (see [`CHANNEL_SCOPE`]) is flagged; those paths must
//!   use `sync_channel` + `try_send` and degrade (drop, run inline, or
//!   report a typed error) instead of growing an unbounded queue.
//!
//! Approximations, all deliberate and all conservative (they can over-hold
//! a guard, never under-hold it): a `let`-bound guard lives to the end of
//! its enclosing brace scope or an explicit `drop(name)`; a non-`let`
//! acquisition is a statement temporary living to the next `;`; closures
//! passed to `spawn` / `execute` / `map_chunks` run on other threads, so
//! they are analyzed as fresh contexts with an empty held stack and do not
//! contribute to the enclosing function's summary; calls whose arguments
//! mention `Ordering` are atomic operations, not lock-taking calls; bodies
//! of `mod tests` are skipped entirely (the tree's poisoning and deliberate
//! inversion tests live there and are exercised at runtime by the sentinel
//! instead). Interprocedural propagation is restricted to calls whose
//! callee is unambiguous at token level — bare calls (`helper(..)`) and
//! `self.method(..)` — because a dotted call on an arbitrary receiver
//! (`guard.recv()`) or a path-qualified call (`Arc::new(..)`) merging by
//! simple name with unrelated `fn recv` / `fn new` definitions fabricates
//! edges the code cannot take; within that restriction summaries merge by
//! simple name, which can only add edges, never hide one.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

use crate::lexer::{Tok, TokKind};
use crate::rules::{
    depth_delta, ident_text, is_ident, is_punct, matching_close, Finding, SourceFile,
};

pub const LOCK_ORDER: &str = "lock-order";
pub const ATOMIC_ORDERING: &str = "atomic-ordering";
pub const RANK_TABLE_SYNC: &str = "rank-table-sync";
pub const UNBOUNDED_CHANNEL: &str = "unbounded-channel";

/// Every analyze rule, with a one-line summary (`opdr-lint --list-rules`).
pub const ANALYZE_RULES: &[(&str, &str)] = &[
    (
        LOCK_ORDER,
        "cycle in the cross-file acquired-while-holding lock graph; a deadlock waiting for the right interleaving",
    ),
    (
        ATOMIC_ORDERING,
        "Ordering::Relaxed needs an // ORDERING: justification comment within the 6 preceding lines",
    ),
    (
        RANK_TABLE_SYNC,
        "the util::sync rank table and the statically observed acquisition order must agree both ways",
    ),
    (
        UNBOUNDED_CHANNEL,
        "serving/build paths must use sync_channel + try_send (drop/degrade), never an unbounded mpsc::channel()",
    ),
];

/// File whose `LockRank::new("site", rank)` constants define the rank table.
const RANK_TABLE_FILE: &str = "util/sync.rs";

/// Serving/build-path files where an unbounded `mpsc::channel()` is a
/// backpressure bug (scoped like `bounded-prealloc`, so token matching has
/// no false positives elsewhere).
const CHANNEL_SCOPE: &[&str] =
    &["pool.rs", "index/shard.rs", "coordinator/server.rs", "telemetry/probe.rs"];

/// How many lines above an `Ordering::Relaxed` the `// ORDERING:` comment
/// may start (mirrors `SAFETY_WINDOW`).
const ORDERING_WINDOW: usize = 6;

/// Calls whose closure arguments run on another thread: analyzed as fresh
/// contexts, excluded from the enclosing function's summary.
const SPAWN_LIKE: &[&str] = &["spawn", "execute", "map_chunks"];

/// Idents that look like calls (`if (..)`, `match (..)`) but are keywords.
const KEYWORDS: &[&str] = &[
    "if", "else", "while", "match", "for", "loop", "return", "let", "in", "as", "move", "ref",
    "break", "continue", "unsafe", "where", "impl", "fn", "pub", "use", "mod", "struct", "enum",
    "trait", "type", "const", "static", "crate", "super", "dyn", "box", "await", "Some", "Ok",
    "Err", "None",
];

// ---------------------------------------------------------------------------
// rank table
// ---------------------------------------------------------------------------

struct RankTable {
    /// const name -> (site name, rank, declaration line).
    consts: BTreeMap<String, (String, u16, usize)>,
    /// site name -> rank.
    ranks: BTreeMap<String, u16>,
    file: PathBuf,
}

/// Parse `const NAME: LockRank = LockRank::new("site", rank);` declarations.
fn parse_rank_table(f: &SourceFile) -> (RankTable, Vec<Finding>) {
    let toks = f.toks();
    let mut table = RankTable {
        consts: BTreeMap::new(),
        ranks: BTreeMap::new(),
        file: f.path.clone(),
    };
    let mut findings = Vec::new();
    for i in 0..toks.len() {
        if !is_ident(toks.get(i), "const") {
            continue;
        }
        let name = match ident_text(toks.get(i + 1)) {
            Some(n) => n.to_string(),
            None => continue,
        };
        // const NAME : LockRank = LockRank :: new ( "site" , rank )
        if !(is_punct(toks.get(i + 2), ":")
            && is_ident(toks.get(i + 3), "LockRank")
            && is_punct(toks.get(i + 4), "=")
            && is_ident(toks.get(i + 5), "LockRank")
            && is_punct(toks.get(i + 6), ":")
            && is_punct(toks.get(i + 7), ":")
            && is_ident(toks.get(i + 8), "new")
            && is_punct(toks.get(i + 9), "("))
        {
            continue;
        }
        let (site, rank) = match (toks.get(i + 10), toks.get(i + 12)) {
            (Some(s), Some(r))
                if s.kind == TokKind::Str
                    && is_punct(toks.get(i + 11), ",")
                    && r.kind == TokKind::Number =>
            {
                match r.text.parse::<u16>() {
                    Ok(v) => (s.text.clone(), v),
                    Err(_) => continue,
                }
            }
            _ => continue,
        };
        let line = toks[i].line;
        if let Some((prev_site, prev_rank, _)) = table.consts.get(&name) {
            findings.push(Finding {
                rule: RANK_TABLE_SYNC,
                file: f.path.clone(),
                line,
                msg: format!(
                    "duplicate rank constant `{name}` (already `{prev_site}` = {prev_rank})"
                ),
            });
            continue;
        }
        if let Some(other) = table.consts.iter().find(|(_, v)| v.0 == site).map(|(k, _)| k.clone())
        {
            findings.push(Finding {
                rule: RANK_TABLE_SYNC,
                file: f.path.clone(),
                line,
                msg: format!("duplicate site name `{site}` (also declared by `{other}`)"),
            });
        }
        if let Some(other_name) =
            table.consts.iter().find(|(_, v)| v.1 == rank).map(|(k, _)| k.clone())
        {
            findings.push(Finding {
                rule: RANK_TABLE_SYNC,
                file: f.path.clone(),
                line,
                msg: format!(
                    "rank {rank} assigned to both `{other_name}` and `{name}`; ranks must be \
                     unique for a total order"
                ),
            });
        }
        table.ranks.insert(site.clone(), rank);
        table.consts.insert(name, (site, rank, line));
    }
    (table, findings)
}

// ---------------------------------------------------------------------------
// per-function scan
// ---------------------------------------------------------------------------

/// Everything the scan learns, before the interprocedural expansion.
#[derive(Default)]
struct Analysis {
    /// context name -> sites it acquires directly (normal thread context).
    direct: BTreeMap<String, BTreeSet<String>>,
    /// context name -> callees invoked in normal context.
    callees: BTreeMap<String, BTreeSet<String>>,
    /// (held site -> acquired site) -> first location observed.
    edges: BTreeMap<(String, String), (PathBuf, usize)>,
    /// Calls made while holding guards: (held sites, callee, file, line).
    pending_calls: Vec<(Vec<String>, String, PathBuf, usize)>,
    /// Rank constants referenced at `lock_recover_ranked` call sites.
    used_consts: BTreeSet<String>,
    /// `lock_recover_ranked` call sites whose constant the table lacks.
    unknown_consts: Vec<(String, PathBuf, usize)>,
}

struct Guard {
    /// Binding name when `let`-bound; `None` for statement temporaries.
    name: Option<String>,
    site: String,
    /// Token index past which the guard is no longer held.
    dies_at: usize,
    alive: bool,
}

/// Scan one function (or closure) body for acquisitions, guard lifetimes,
/// calls-under-guard and nested fresh contexts.
fn scan_body(
    sf: &SourceFile,
    start: usize,
    end: usize,
    ctx: &str,
    table: Option<&RankTable>,
    an: &mut Analysis,
) {
    let toks = sf.toks();
    let stem = file_stem(&sf.norm);
    let mut guards: Vec<Guard> = Vec::new();
    // Stack of `}` indices for the brace scopes currently open inside the
    // body; a `let`-bound guard dies at the top of this stack.
    let mut scopes: Vec<usize> = Vec::new();
    let mut i = start;
    while i < end {
        for g in guards.iter_mut() {
            if g.alive && g.dies_at <= i {
                g.alive = false;
            }
        }
        let t = &toks[i];
        if t.kind == TokKind::Punct && t.text == "{" {
            if let Some(close) = matching_close(toks, i) {
                scopes.push(close);
            }
            i += 1;
            continue;
        }
        if t.kind == TokKind::Punct && t.text == "}" {
            if scopes.last() == Some(&i) {
                scopes.pop();
            }
            i += 1;
            continue;
        }
        if t.kind != TokKind::Ident {
            i += 1;
            continue;
        }

        // Nested `fn` definitions are their own context.
        if t.text == "fn" {
            if let Some((name, body_open, body_close)) = fn_def_at(toks, i, end) {
                scan_body(sf, body_open + 1, body_close, &name, table, an);
                i = body_close + 1;
                continue;
            }
            i += 1;
            continue;
        }

        // `drop(name)` releases a let-bound guard early.
        if t.text == "drop" && is_punct(toks.get(i + 1), "(") {
            if let (Some(name), true) = (ident_text(toks.get(i + 2)), is_punct(toks.get(i + 3), ")"))
            {
                if let Some(g) = guards
                    .iter_mut()
                    .rev()
                    .find(|g| g.alive && g.name.as_deref() == Some(name))
                {
                    g.alive = false;
                }
                i += 4;
                continue;
            }
        }

        // Closures handed to another thread: fresh context, no summary leak.
        if SPAWN_LIKE.contains(&t.text.as_str())
            && is_punct(toks.get(i + 1), "(")
            && !is_ident(i.checked_sub(1).and_then(|j| toks.get(j)), "fn")
        {
            if let Some(close) = matching_close(toks, i + 1) {
                if unambiguous_callee(toks, i) {
                    record_call(ctx, t, &guards, sf, an);
                }
                let fresh = format!("{ctx}@{}", t.line);
                scan_body(sf, i + 2, close, &fresh, table, an);
                i = close + 1;
                continue;
            }
        }

        // Acquisition.
        if (t.text == "lock_recover" || t.text == "lock_recover_ranked")
            && is_punct(toks.get(i + 1), "(")
            && !is_ident(i.checked_sub(1).and_then(|j| toks.get(j)), "fn")
        {
            let close = match matching_close(toks, i + 1) {
                Some(c) => c,
                None => {
                    i += 1;
                    continue;
                }
            };
            let site = if t.text == "lock_recover_ranked" {
                match ranked_site(toks, i + 1, close, table) {
                    RankedSite::Known(cname, site) => {
                        an.used_consts.insert(cname);
                        site
                    }
                    RankedSite::Unknown(cname) => {
                        an.unknown_consts.push((cname.clone(), sf.path.clone(), t.line));
                        cname
                    }
                    RankedSite::Unresolved => format!("{stem}.?ranked"),
                }
            } else {
                format!("{stem}.{}", plain_site(toks, i + 1, close))
            };
            for g in guards.iter().filter(|g| g.alive) {
                an.edges
                    .entry((g.site.clone(), site.clone()))
                    .or_insert_with(|| (sf.path.clone(), t.line));
            }
            an.direct.entry(ctx.to_string()).or_default().insert(site.clone());
            let (name, dies_at) = guard_lifetime(toks, i, close, &scopes, end);
            guards.push(Guard { name, site, dies_at, alive: true });
            i += 1;
            continue;
        }

        // Plain call.
        if is_punct(toks.get(i + 1), "(")
            && !KEYWORDS.contains(&t.text.as_str())
            && !is_ident(i.checked_sub(1).and_then(|j| toks.get(j)), "fn")
        {
            if let Some(close) = matching_close(toks, i + 1) {
                // Atomic ops (`.load(Ordering::..)`, `fetch_add(1, Ordering::..)`)
                // are not lock-taking calls.
                let atomic =
                    toks[i + 2..close].iter().any(|a| a.kind == TokKind::Ident && a.text == "Ordering");
                if !atomic && unambiguous_callee(toks, i) {
                    record_call(ctx, t, &guards, sf, an);
                }
            }
        }
        i += 1;
    }
}

/// Should a call at token `i` propagate through function summaries? Only
/// when the callee name is unambiguous at token level: a bare call
/// (`helper(..)`) names a local free function, and `self.method(..)` names
/// a method of the enclosing type. A dotted call on any other receiver
/// (`guard.recv()`, `g.ring.len()`) or a path-qualified call
/// (`Arc::new(..)`, `DeltaIndex::from_parts(..)`) would merge by simple
/// name with unrelated `fn recv` / `fn len` / `fn new` definitions
/// elsewhere in the corpus and fabricate edges the code cannot take.
fn unambiguous_callee(toks: &[Tok], i: usize) -> bool {
    let prev = i.checked_sub(1).and_then(|j| toks.get(j));
    if is_punct(prev, ".") {
        return is_ident(i.checked_sub(2).and_then(|j| toks.get(j)), "self");
    }
    if is_punct(prev, ":") {
        return false;
    }
    true
}

fn record_call(ctx: &str, callee: &Tok, guards: &[Guard], sf: &SourceFile, an: &mut Analysis) {
    an.callees.entry(ctx.to_string()).or_default().insert(callee.text.clone());
    let held: Vec<String> =
        guards.iter().filter(|g| g.alive).map(|g| g.site.clone()).collect();
    if !held.is_empty() {
        an.pending_calls.push((held, callee.text.clone(), sf.path.clone(), callee.line));
    }
}

enum RankedSite {
    /// (const name, site name) — the constant exists in the table.
    Known(String, String),
    /// Constant name not declared in the table.
    Unknown(String),
    /// Second argument had no identifier at all.
    Unresolved,
}

/// Resolve the rank argument of `lock_recover_ranked(&m, ranks::NAME)`:
/// the last identifier of the expression after the first top-level comma.
fn ranked_site(toks: &[Tok], open: usize, close: usize, table: Option<&RankTable>) -> RankedSite {
    let mut depth = 0isize;
    let mut comma = None;
    for (j, t) in toks.iter().enumerate().take(close).skip(open + 1) {
        depth += depth_delta(t);
        if depth == 0 && t.kind == TokKind::Punct && t.text == "," {
            comma = Some(j);
            break;
        }
    }
    let comma = match comma {
        Some(c) => c,
        None => return RankedSite::Unresolved,
    };
    let cname = toks[comma + 1..close]
        .iter()
        .rev()
        .find(|t| t.kind == TokKind::Ident)
        .map(|t| t.text.clone());
    match cname {
        Some(cname) => match table.and_then(|tb| tb.consts.get(&cname)) {
            Some((site, _, _)) => RankedSite::Known(cname, site.clone()),
            None if table.is_some() => RankedSite::Unknown(cname),
            None => RankedSite::Known(cname.clone(), cname),
        },
        None => RankedSite::Unresolved,
    }
}

/// Site name for a plain `lock_recover(&self.field)` acquisition: the last
/// identifier of the argument expression.
fn plain_site(toks: &[Tok], open: usize, close: usize) -> String {
    toks[open + 1..close]
        .iter()
        .rev()
        .find(|t| t.kind == TokKind::Ident)
        .map(|t| t.text.clone())
        .unwrap_or_else(|| "?".to_string())
}

/// Determine how long the guard born at token `acq` (call close paren at
/// `close`) lives: `let`-bound guards live to the end of the innermost open
/// brace scope; otherwise the acquisition is a statement temporary living
/// to the next `;` at relative bracket depth zero.
fn guard_lifetime(
    toks: &[Tok],
    acq: usize,
    close: usize,
    scopes: &[usize],
    end: usize,
) -> (Option<String>, usize) {
    // Walk back over a `path::to::` prefix.
    let mut j = acq;
    while j >= 3
        && is_punct(toks.get(j - 1), ":")
        && is_punct(toks.get(j - 2), ":")
        && toks.get(j - 3).map(|t| t.kind == TokKind::Ident).unwrap_or(false)
    {
        j -= 3;
    }
    if j >= 1 && is_punct(toks.get(j - 1), "=") {
        // Search back to the statement boundary for `let name =`.
        let mut k = j - 1;
        let mut steps = 0;
        while k > 0 && steps < 16 {
            let t = &toks[k - 1];
            if t.kind == TokKind::Punct && matches!(t.text.as_str(), ";" | "{" | "}") {
                break;
            }
            if t.kind == TokKind::Ident && t.text == "let" {
                let name = toks[k..j]
                    .iter()
                    .find(|t| t.kind == TokKind::Ident && t.text != "mut")
                    .map(|t| t.text.clone());
                let dies_at = scopes.last().copied().unwrap_or(end);
                return (name, dies_at);
            }
            k -= 1;
            steps += 1;
        }
    }
    // Statement temporary: next `;` at relative depth 0, or expression end.
    let mut depth = 0isize;
    for (k, t) in toks.iter().enumerate().take(end).skip(close + 1) {
        depth += depth_delta(t);
        if depth < 0 {
            return (None, k);
        }
        if depth == 0 && t.kind == TokKind::Punct && t.text == ";" {
            return (None, k);
        }
    }
    (None, end)
}

/// `fn NAME .. { .. }` starting at the `fn` keyword: returns the name and
/// the body's brace span. `None` for bodyless trait-method declarations.
fn fn_def_at(toks: &[Tok], at: usize, end: usize) -> Option<(String, usize, usize)> {
    let name = ident_text(toks.get(at + 1))?.to_string();
    let mut paren = 0isize;
    let mut j = at + 2;
    while j < end {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" => paren += 1,
                ")" | "]" => paren -= 1,
                "{" if paren == 0 => {
                    let close = matching_close(toks, j)?;
                    return Some((name, j, close));
                }
                ";" if paren == 0 => return None,
                _ => {}
            }
        }
        j += 1;
    }
    None
}

fn file_stem(norm: &str) -> String {
    norm.rsplit('/')
        .next()
        .unwrap_or(norm)
        .trim_end_matches(".rs")
        .to_string()
}

/// Token spans the top-level walker must not enter: `mod tests { .. }`
/// bodies and the `lock_recover` / `lock_recover_ranked` definitions
/// themselves (they are the acquisition primitives, not users).
fn skip_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if is_ident(toks.get(i), "mod")
            && is_ident(toks.get(i + 1), "tests")
            && is_punct(toks.get(i + 2), "{")
        {
            if let Some(close) = matching_close(toks, i + 2) {
                out.push((i, close));
            }
        }
        if is_ident(toks.get(i), "fn")
            && (is_ident(toks.get(i + 1), "lock_recover")
                || is_ident(toks.get(i + 1), "lock_recover_ranked"))
        {
            if let Some((_, _, close)) = fn_def_at(toks, i, toks.len()) {
                out.push((i, close));
            }
        }
    }
    out
}

fn scan_file(sf: &SourceFile, table: Option<&RankTable>, an: &mut Analysis) {
    let toks = sf.toks();
    let skips = skip_ranges(toks);
    let mut i = 0usize;
    while i < toks.len() {
        if let Some(&(_, close)) = skips.iter().find(|&&(s, e)| s <= i && i <= e) {
            i = close + 1;
            continue;
        }
        if is_ident(toks.get(i), "fn") {
            if let Some((name, open, close)) = fn_def_at(toks, i, toks.len()) {
                if !skips.iter().any(|&(s, e)| s <= open && open <= e) {
                    scan_body(sf, open + 1, close, &name, table, an);
                    i = close + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// interprocedural expansion + cycle detection
// ---------------------------------------------------------------------------

/// summary(f) = direct(f) ∪ ⋃ summary(callees(f)), to fixpoint.
fn summaries(an: &Analysis) -> BTreeMap<String, BTreeSet<String>> {
    let mut sum = an.direct.clone();
    loop {
        let mut changed = false;
        for (ctx, callees) in &an.callees {
            let mut add: BTreeSet<String> = BTreeSet::new();
            for c in callees {
                if let Some(s) = sum.get(c) {
                    add.extend(s.iter().cloned());
                }
            }
            if !add.is_empty() {
                let entry = sum.entry(ctx.clone()).or_default();
                let before = entry.len();
                entry.extend(add);
                changed |= entry.len() != before;
            }
        }
        if !changed {
            return sum;
        }
    }
}

/// Expand calls-under-guard through the summaries into extra edges.
fn expand_edges(an: &mut Analysis) {
    let sums = summaries(an);
    let pending = std::mem::take(&mut an.pending_calls);
    for (held, callee, file, line) in pending {
        if let Some(sites) = sums.get(&callee) {
            for s in sites {
                for h in &held {
                    an.edges
                        .entry((h.clone(), s.clone()))
                        .or_insert_with(|| (file.clone(), line));
                }
            }
        }
    }
}

/// DFS cycle detection; one finding per distinct cycle, anchored at the
/// recorded location of the edge that closes it.
fn find_cycles(edges: &BTreeMap<(String, String), (PathBuf, usize)>) -> Vec<Finding> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (from, to) in edges.keys() {
        adj.entry(from.as_str()).or_default().push(to.as_str());
        adj.entry(to.as_str()).or_default();
    }
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Gray,
        Black,
    }
    let mut color: BTreeMap<&str, Color> =
        adj.keys().map(|&n| (n, Color::White)).collect();
    let mut stack: Vec<&str> = Vec::new();
    let mut seen_cycles: BTreeSet<Vec<String>> = BTreeSet::new();
    let mut out = Vec::new();

    fn dfs<'a>(
        node: &'a str,
        adj: &BTreeMap<&'a str, Vec<&'a str>>,
        color: &mut BTreeMap<&'a str, Color>,
        stack: &mut Vec<&'a str>,
        edges: &BTreeMap<(String, String), (PathBuf, usize)>,
        seen: &mut BTreeSet<Vec<String>>,
        out: &mut Vec<Finding>,
    ) {
        color.insert(node, Color::Gray);
        stack.push(node);
        for &next in adj.get(node).map(|v| v.as_slice()).unwrap_or(&[]) {
            match color.get(next).copied().unwrap_or(Color::White) {
                Color::White => dfs(next, adj, color, stack, edges, seen, out),
                Color::Gray => {
                    let from = stack.iter().position(|&n| n == next).unwrap_or(0);
                    let mut cycle: Vec<String> =
                        stack[from..].iter().map(|s| s.to_string()).collect();
                    cycle.push(next.to_string());
                    // Canonical signature: rotate so the smallest node leads.
                    let body = &cycle[..cycle.len() - 1];
                    let min = body.iter().enumerate().min_by_key(|(_, s)| s.clone());
                    let rot = min.map(|(i, _)| i).unwrap_or(0);
                    let mut sig: Vec<String> = body[rot..].to_vec();
                    sig.extend_from_slice(&body[..rot]);
                    if seen.insert(sig) {
                        let (file, line) = edges
                            .get(&(node.to_string(), next.to_string()))
                            .cloned()
                            .unwrap_or((PathBuf::from("?"), 0));
                        out.push(Finding {
                            rule: LOCK_ORDER,
                            file,
                            line,
                            msg: format!(
                                "{} — acquiring these locks in both orders deadlocks under \
                                 the right interleaving; pick one order and encode it in the \
                                 util::sync rank table",
                                cycle.join(" -> ")
                            ),
                        });
                    }
                }
                Color::Black => {}
            }
        }
        stack.pop();
        color.insert(node, Color::Black);
    }

    let nodes: Vec<&str> = adj.keys().copied().collect();
    for n in nodes {
        if color.get(n).copied() == Some(Color::White) {
            dfs(n, &adj, &mut color, &mut stack, edges, &mut seen_cycles, &mut out);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// per-file rules: atomic-ordering, unbounded-channel
// ---------------------------------------------------------------------------

fn atomic_ordering(f: &SourceFile) -> Vec<Finding> {
    let toks = f.toks();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !(is_ident(toks.get(i), "Ordering")
            && is_punct(toks.get(i + 1), ":")
            && is_punct(toks.get(i + 2), ":")
            && is_ident(toks.get(i + 3), "Relaxed"))
        {
            continue;
        }
        let line = toks[i].line;
        let covered = f.lexed.comments.iter().any(|c| {
            c.text.contains("ORDERING:") && c.line <= line && line - c.line <= ORDERING_WINDOW
        });
        if !covered {
            out.push(Finding {
                rule: ATOMIC_ORDERING,
                file: f.path.clone(),
                line,
                msg: format!(
                    "`Ordering::Relaxed` without an `// ORDERING:` comment in the \
                     {ORDERING_WINDOW} lines above it; state why no cross-thread \
                     publication depends on this operation's ordering"
                ),
            });
        }
    }
    out
}

fn unbounded_channel(f: &SourceFile) -> Vec<Finding> {
    if !CHANNEL_SCOPE.iter().any(|s| f.norm.ends_with(s)) {
        return Vec::new();
    }
    let toks = f.toks();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !is_ident(toks.get(i), "channel") {
            continue;
        }
        // Skip an optional `::<T>` turbofish.
        let mut j = i + 1;
        if is_punct(toks.get(j), ":") && is_punct(toks.get(j + 1), ":") && is_punct(toks.get(j + 2), "<")
        {
            let mut depth = 0isize;
            let mut k = j + 2;
            while k < toks.len() {
                match toks[k].text.as_str() {
                    "<" => depth += 1,
                    ">" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            j = k + 1;
        }
        if is_punct(toks.get(j), "(") && is_punct(toks.get(j + 1), ")") {
            out.push(Finding {
                rule: UNBOUNDED_CHANNEL,
                file: f.path.clone(),
                line: toks[i].line,
                msg: "unbounded `mpsc::channel()` on a serving/build path; use \
                      `sync_channel(cap)` + `try_send` and degrade on `Full` \
                      (drop, run inline, or return a typed error) so a slow \
                      consumer applies backpressure instead of growing the heap"
                    .to_string(),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// entry points
// ---------------------------------------------------------------------------

/// Analyze an in-memory corpus of `(path, source)` pairs. Pure — the
/// fixture tests drive this; `analyze_paths` in `lib.rs` wraps it with the
/// filesystem walk. Findings come back sorted by (file, line, rule).
pub fn analyze_sources(files: &[(PathBuf, String)]) -> Vec<Finding> {
    let parsed: Vec<SourceFile> =
        files.iter().map(|(p, s)| SourceFile::new(p.clone(), s)).collect();
    let mut findings = Vec::new();

    let table_file = parsed.iter().find(|f| f.norm.ends_with(RANK_TABLE_FILE));
    let table = table_file.map(|f| {
        let (table, table_findings) = parse_rank_table(f);
        findings.extend(table_findings);
        table
    });

    let mut an = Analysis::default();
    for f in &parsed {
        scan_file(f, table.as_ref(), &mut an);
        findings.extend(atomic_ordering(f));
        findings.extend(unbounded_channel(f));
    }
    expand_edges(&mut an);

    findings.extend(find_cycles(&an.edges));

    if let Some(table) = &table {
        // Direction 1: every declared constant is used at some call site.
        for (cname, (site, _, line)) in &table.consts {
            if !an.used_consts.contains(cname) {
                findings.push(Finding {
                    rule: RANK_TABLE_SYNC,
                    file: table.file.clone(),
                    line: *line,
                    msg: format!(
                        "rank constant `{cname}` (`{site}`) is never passed to \
                         `lock_recover_ranked`; remove it or rank the lock it names"
                    ),
                });
            }
        }
        // Direction 2: every ranked call site names a declared constant.
        for (cname, file, line) in &an.unknown_consts {
            findings.push(Finding {
                rule: RANK_TABLE_SYNC,
                file: file.clone(),
                line: *line,
                msg: format!(
                    "`lock_recover_ranked` uses `{cname}`, which is not declared in the \
                     {RANK_TABLE_FILE} rank table"
                ),
            });
        }
        // Direction 3: observed edges between ranked sites must be
        // rank-increasing — the static order and the runtime sentinel agree.
        for ((from, to), (file, line)) in &an.edges {
            if let (Some(&rf), Some(&rt)) = (table.ranks.get(from), table.ranks.get(to)) {
                if rf >= rt {
                    findings.push(Finding {
                        rule: RANK_TABLE_SYNC,
                        file: file.clone(),
                        line: *line,
                        msg: format!(
                            "`{to}` (rank {rt}) acquired while holding `{from}` (rank {rf}); \
                             the rank table requires strictly increasing acquisition — \
                             reorder the code or renumber the table"
                        ),
                    });
                }
            }
        }
    }

    // Escape hatch + deterministic order, same as `lint_sources`.
    let by_path: BTreeMap<&str, &SourceFile> =
        parsed.iter().map(|f| (f.norm.as_str(), f)).collect();
    findings.retain(|fi| {
        let norm: String = fi
            .file
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        by_path.get(norm.as_str()).map(|sf| !sf.allowed(fi.rule, fi.line)).unwrap_or(true)
    });
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings
}

#[cfg(test)]
mod unit {
    use super::*;

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let corpus: Vec<(PathBuf, String)> =
            files.iter().map(|(p, s)| (PathBuf::from(p), s.to_string())).collect();
        analyze_sources(&corpus)
    }

    #[test]
    fn ab_ba_inversion_is_a_cycle() {
        let src = "fn a(s: &S) { let x = lock_recover(&s.p); let y = lock_recover(&s.q); y.t(*x); }\n\
                   fn b(s: &S) { let y = lock_recover(&s.q); let x = lock_recover(&s.p); x.t(*y); }\n";
        let f = run(&[("rust/src/m/fx.rs", src)]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, LOCK_ORDER);
        assert!(f[0].msg.contains("fx.p -> fx.q -> fx.p"), "{}", f[0].msg);
    }

    #[test]
    fn receiver_ambiguous_calls_do_not_propagate() {
        // `fn recv` in the corpus takes a lock; a *dotted* call `g.recv()`
        // under another guard must not inherit its acquisitions — only a
        // bare call or `self.recv()` names that function unambiguously.
        let src = "fn recv(s: &S) { let q = lock_recover(&s.q); q.t(); }\n\
                   fn b(s: &S) { let y = lock_recover(&s.q); let x = lock_recover(&s.p); x.t(*y); }\n\
                   fn dotted(s: &S, g: &G) { let x = lock_recover(&s.p); g.recv(); x.t(); }\n";
        assert!(run(&[("rust/src/m/fx.rs", src)]).is_empty());

        let bare = src.replace("g.recv();", "recv(s);");
        let f = run(&[("rust/src/m/fx.rs", &bare)]);
        assert_eq!(f.len(), 1, "bare call must close the cycle: {f:?}");
        assert_eq!(f[0].rule, LOCK_ORDER);

        let self_call = src.replace("g.recv();", "self.recv();");
        let f = run(&[("rust/src/m/fx.rs", &self_call)]);
        assert_eq!(f.len(), 1, "self call must close the cycle: {f:?}");
    }

    #[test]
    fn statement_temporary_dies_at_semicolon() {
        // Non-let acquisitions live to the end of the statement only, so
        // two consecutive statement temporaries never overlap.
        let src = "fn a(s: &S) { *lock_recover(&s.p) += 1; *lock_recover(&s.q) += 1; }\n\
                   fn b(s: &S) { *lock_recover(&s.q) += 1; *lock_recover(&s.p) += 1; }\n";
        assert!(run(&[("rust/src/m/fx.rs", src)]).is_empty());
    }

    #[test]
    fn spawned_closures_are_fresh_contexts() {
        // The closure body runs on another thread: a lock taken inside it
        // is not acquired-while-holding the spawner's guard.
        let src = "fn a(s: &S) { let x = lock_recover(&s.p); spawn(move || { let y = lock_recover(&s.q); y.t(); }); x.t(); }\n\
                   fn b(s: &S) { let y = lock_recover(&s.q); let x = lock_recover(&s.p); x.t(*y); }\n";
        assert!(run(&[("rust/src/m/fx.rs", src)]).is_empty());
    }
}
