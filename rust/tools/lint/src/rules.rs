//! The opdr repo-invariant rules.
//!
//! Each rule is a named check over the token/comment streams of one file
//! (or, for the doc-sync rules, a pair of files). Every rule honours the
//! `// lint:allow(rule-name)` / `// lint:allow(rule-name: reason)` escape
//! hatch placed on the flagged line or up to two lines above it; the reason
//! clause is free text and is encouraged.
//!
//! See `rust/tools/lint/README.md` for the rule catalogue with the PR that
//! established each invariant.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::PathBuf;

use crate::lexer::{lex, Comment, Lexed, Tok, TokKind};

/// One diagnostic. Rendered as `file:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: PathBuf,
    /// 1-based line.
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.msg)
    }
}

pub const NO_PARTIAL_CMP_ORDERING: &str = "no-partial-cmp-ordering";
pub const NO_NAKED_LOCK_UNWRAP: &str = "no-naked-lock-unwrap";
pub const BOUNDED_PREALLOC: &str = "bounded-prealloc";
pub const UNSAFE_NEEDS_SAFETY_COMMENT: &str = "unsafe-needs-safety-comment";
pub const METRIC_DOCS_SYNC: &str = "metric-docs-sync";
pub const CONFIG_DOCS_SYNC: &str = "config-docs-sync";
pub const NO_BLANKET_ALLOW: &str = "no-blanket-allow";

/// Every rule, with a one-line summary (surfaced by `opdr-lint --list-rules`).
pub const RULES: &[(&str, &str)] = &[
    (
        NO_PARTIAL_CMP_ORDERING,
        "comparators must use total_cmp; partial_cmp(..).unwrap*() hides NaN ordering (PR 4/5)",
    ),
    (
        NO_NAKED_LOCK_UNWRAP,
        ".lock().unwrap() poisons-cascade across threads; use util::lock_recover (PR 4)",
    ),
    (
        BOUNDED_PREALLOC,
        "decode-path allocations sized by wire data must go through the ALLOC_CHUNK-bounded io helpers (PR 5/7)",
    ),
    (
        UNSAFE_NEEDS_SAFETY_COMMENT,
        "every `unsafe` needs a // SAFETY: comment within the 6 preceding lines (PR 5)",
    ),
    (
        METRIC_DOCS_SYNC,
        "telemetry opdr_* name constants and the coordinator module-docs metrics table must agree both ways (PR 6/8)",
    ),
    (
        CONFIG_DOCS_SYNC,
        "every [serve]/[dist] key accepted by config/schema.rs must appear in its module-docs key tables",
    ),
    (
        NO_BLANKET_ALLOW,
        "no #![allow(..)] or blanket #[allow(warnings|clippy::all|dead_code|unused)]; scope narrow allows per item",
    ),
];

/// A lexed source file plus its escape-hatch annotations.
pub struct SourceFile {
    pub path: PathBuf,
    /// Path with `/` separators, for suffix-based scoping.
    pub(crate) norm: String,
    pub(crate) lexed: Lexed,
    /// rule name -> comment lines carrying a `lint:allow` for it.
    allows: HashMap<String, Vec<usize>>,
}

impl SourceFile {
    pub fn new(path: PathBuf, src: &str) -> Self {
        let lexed = lex(src);
        let allows = parse_allows(&lexed.comments);
        let norm = path
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        SourceFile { path, norm, lexed, allows }
    }

    pub(crate) fn toks(&self) -> &[Tok] {
        &self.lexed.tokens
    }

    /// Is a finding of `rule` at `line` suppressed by a `lint:allow` on the
    /// same line or within the two lines above it?
    pub(crate) fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows
            .get(rule)
            .map(|lines| lines.iter().any(|&l| l <= line && line <= l + 2))
            .unwrap_or(false)
    }
}

/// Extract `lint:allow(rule)` / `lint:allow(rule: reason)` escape hatches.
/// One comment may carry several.
fn parse_allows(comments: &[Comment]) -> HashMap<String, Vec<usize>> {
    let mut out: HashMap<String, Vec<usize>> = HashMap::new();
    for c in comments {
        let mut rest = c.text.as_str();
        while let Some(at) = rest.find("lint:allow(") {
            rest = &rest[at + "lint:allow(".len()..];
            let end = match rest.find(')') {
                Some(e) => e,
                None => break,
            };
            let inner = &rest[..end];
            let rule = inner.split(':').next().unwrap_or("").trim();
            if !rule.is_empty() {
                out.entry(rule.to_string()).or_default().push(c.line);
            }
            rest = &rest[end + 1..];
        }
    }
    out
}

/// Lint an in-memory corpus of `(path, source)` pairs. Pure — this is what
/// the fixture tests drive; `lint_paths` in `lib.rs` wraps it with the
/// filesystem walk. Findings come back sorted by (file, line, rule).
pub fn lint_sources(files: &[(PathBuf, String)]) -> Vec<Finding> {
    let parsed: Vec<SourceFile> =
        files.iter().map(|(p, s)| SourceFile::new(p.clone(), s)).collect();
    let mut findings = Vec::new();
    for f in &parsed {
        findings.extend(no_partial_cmp_ordering(f));
        findings.extend(no_naked_lock_unwrap(f));
        findings.extend(bounded_prealloc(f));
        findings.extend(unsafe_needs_safety_comment(f));
        findings.extend(no_blanket_allow(f));
    }
    findings.extend(metric_docs_sync(&parsed));
    findings.extend(config_docs_sync(&parsed));

    // Apply the escape hatch uniformly, including to doc-sync findings.
    let by_path: HashMap<&str, &SourceFile> =
        parsed.iter().map(|f| (f.norm.as_str(), f)).collect();
    findings.retain(|fi| {
        let norm: String = fi
            .file
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        by_path.get(norm.as_str()).map(|sf| !sf.allowed(fi.rule, fi.line)).unwrap_or(true)
    });
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    findings
}

// ---------------------------------------------------------------------------
// token helpers
// ---------------------------------------------------------------------------

pub(crate) fn is_punct(t: Option<&Tok>, c: &str) -> bool {
    matches!(t, Some(t) if t.kind == TokKind::Punct && t.text == c)
}

pub(crate) fn is_ident(t: Option<&Tok>, name: &str) -> bool {
    matches!(t, Some(t) if t.kind == TokKind::Ident && t.text == name)
}

pub(crate) fn ident_text(t: Option<&Tok>) -> Option<&str> {
    match t {
        Some(t) if t.kind == TokKind::Ident => Some(&t.text),
        _ => None,
    }
}

/// Index of the `)`/`]`/`}` matching the opener at `open`, if any.
pub(crate) fn matching_close(toks: &[Tok], open: usize) -> Option<usize> {
    let (o, c) = match toks[open].text.as_str() {
        "(" => ("(", ")"),
        "[" => ("[", "]"),
        "{" => ("{", "}"),
        _ => return None,
    };
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate().skip(open) {
        if t.kind == TokKind::Punct {
            if t.text == o {
                depth += 1;
            } else if t.text == c {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
        }
    }
    None
}

/// Nesting delta contributed by a punct token (any bracket flavour).
pub(crate) fn depth_delta(t: &Tok) -> isize {
    if t.kind != TokKind::Punct {
        return 0;
    }
    match t.text.as_str() {
        "(" | "[" | "{" => 1,
        ")" | "]" | "}" => -1,
        _ => 0,
    }
}

// ---------------------------------------------------------------------------
// rule: no-partial-cmp-ordering
// ---------------------------------------------------------------------------

fn no_partial_cmp_ordering(f: &SourceFile) -> Vec<Finding> {
    let toks = f.toks();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !is_ident(toks.get(i), "partial_cmp") || !is_punct(i.checked_sub(1).and_then(|j| toks.get(j)), ".") {
            continue; // `fn partial_cmp` definitions are fine; only call sites count
        }
        if !is_punct(toks.get(i + 1), "(") {
            continue;
        }
        let close = match matching_close(toks, i + 1) {
            Some(c) => c,
            None => continue,
        };
        if is_punct(toks.get(close + 1), ".") {
            if let Some(next) = ident_text(toks.get(close + 2)) {
                if matches!(
                    next,
                    "unwrap" | "expect" | "unwrap_or" | "unwrap_or_else" | "unwrap_or_default"
                ) {
                    out.push(Finding {
                        rule: NO_PARTIAL_CMP_ORDERING,
                        file: f.path.clone(),
                        line: toks[i].line,
                        msg: format!(
                            "`.partial_cmp(..).{next}(..)` panics or silently reorders on NaN; \
                             use `total_cmp` (PR 4/5 NaN sweeps), or pre-filter NaNs and \
                             `// lint:allow({NO_PARTIAL_CMP_ORDERING}: ..)` with a NaN test"
                        ),
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// rule: no-naked-lock-unwrap
// ---------------------------------------------------------------------------

fn no_naked_lock_unwrap(f: &SourceFile) -> Vec<Finding> {
    let toks = f.toks();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !is_ident(toks.get(i), "lock") || !is_punct(i.checked_sub(1).and_then(|j| toks.get(j)), ".") {
            continue;
        }
        if !(is_punct(toks.get(i + 1), "(") && is_punct(toks.get(i + 2), ")")) {
            continue;
        }
        if is_punct(toks.get(i + 3), ".") {
            if let Some(next) = ident_text(toks.get(i + 4)) {
                if next == "unwrap" || next == "expect" {
                    out.push(Finding {
                        rule: NO_NAKED_LOCK_UNWRAP,
                        file: f.path.clone(),
                        line: toks[i].line,
                        msg: format!(
                            "`.lock().{next}()` turns one poisoned panic into a cascade; \
                             use `crate::util::lock_recover` (PR 4 poison-recovery convention)"
                        ),
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// rule: bounded-prealloc
// ---------------------------------------------------------------------------

/// Decode-path files where allocation sizes can come off the wire/disk.
const PREALLOC_SCOPE: &[&str] =
    &["data/store.rs", "data/mapped.rs", "rpc/frame.rs", "rpc/fault.rs"];

/// A size expression is considered bounded when it routes through
/// `ALLOC_CHUNK` (e.g. `n.min(ALLOC_CHUNK)`) or contains no runtime
/// identifiers at all (literals and SCREAMING_CASE consts only).
fn size_expr_is_bounded(arg: &[Tok]) -> bool {
    let mut saw_runtime_ident = false;
    for t in arg {
        if t.kind != TokKind::Ident {
            continue;
        }
        if t.text == "ALLOC_CHUNK" {
            return true;
        }
        if t.text.chars().any(|c| c.is_lowercase()) {
            saw_runtime_ident = true;
        }
    }
    !saw_runtime_ident
}

fn bounded_prealloc(f: &SourceFile) -> Vec<Finding> {
    if !PREALLOC_SCOPE.iter().any(|s| f.norm.ends_with(s)) {
        return Vec::new();
    }
    let toks = f.toks();
    let mut out = Vec::new();
    let mut flag = |line: usize, what: &str| {
        out.push(Finding {
            rule: BOUNDED_PREALLOC,
            file: f.path.clone(),
            line,
            msg: format!(
                "{what} sized by a runtime value in a decode path; clamp via the \
                 `ALLOC_CHUNK`-bounded `crate::index::io` helpers \
                 (read_bytes/read_f32s/read_u32s) so corrupt length fields cannot \
                 force huge allocations (PR 5/7 hardening)"
            ),
        });
    };
    for i in 0..toks.len() {
        // Vec::with_capacity / String::with_capacity / BufReader::with_capacity …
        if is_ident(toks.get(i), "with_capacity") && is_punct(toks.get(i + 1), "(") {
            if let Some(close) = matching_close(toks, i + 1) {
                // First top-level argument is the capacity.
                let mut end = close;
                let mut depth = 0isize;
                for (j, t) in toks.iter().enumerate().take(close).skip(i + 2) {
                    depth += depth_delta(t);
                    if depth == 0 && t.kind == TokKind::Punct && t.text == "," {
                        end = j;
                        break;
                    }
                }
                if !size_expr_is_bounded(&toks[i + 2..end]) {
                    flag(toks[i].line, "`with_capacity(..)`");
                }
            }
        }
        // vec![elem; n] repeat form.
        if is_ident(toks.get(i), "vec")
            && is_punct(toks.get(i + 1), "!")
            && is_punct(toks.get(i + 2), "[")
        {
            if let Some(close) = matching_close(toks, i + 2) {
                let mut depth = 0isize;
                let mut semi = None;
                for (j, t) in toks.iter().enumerate().take(close).skip(i + 3) {
                    depth += depth_delta(t);
                    if depth == 0 && t.kind == TokKind::Punct && t.text == ";" {
                        semi = Some(j);
                        break;
                    }
                }
                if let Some(semi) = semi {
                    if !size_expr_is_bounded(&toks[semi + 1..close]) {
                        flag(toks[i].line, "`vec![..; n]`");
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// rule: unsafe-needs-safety-comment
// ---------------------------------------------------------------------------

/// How many lines above an `unsafe` the `// SAFETY:` comment may start.
const SAFETY_WINDOW: usize = 6;

fn unsafe_needs_safety_comment(f: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for t in f.toks() {
        if !(t.kind == TokKind::Ident && t.text == "unsafe") {
            continue;
        }
        let covered = f.lexed.comments.iter().any(|c| {
            c.text.contains("SAFETY:") && c.line <= t.line && t.line - c.line <= SAFETY_WINDOW
        });
        if !covered {
            out.push(Finding {
                rule: UNSAFE_NEEDS_SAFETY_COMMENT,
                file: f.path.clone(),
                line: t.line,
                msg: format!(
                    "`unsafe` without a `// SAFETY:` comment in the {SAFETY_WINDOW} lines \
                     above it; state the invariant that makes this sound (PR 5 mmap convention)"
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// rule: no-blanket-allow
// ---------------------------------------------------------------------------

fn no_blanket_allow(f: &SourceFile) -> Vec<Finding> {
    let toks = f.toks();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !is_punct(toks.get(i), "#") {
            continue;
        }
        let inner = is_punct(toks.get(i + 1), "!");
        let open = if inner { i + 2 } else { i + 1 };
        if !is_punct(toks.get(open), "[") || !is_ident(toks.get(open + 1), "allow") {
            continue;
        }
        if inner {
            out.push(Finding {
                rule: NO_BLANKET_ALLOW,
                file: f.path.clone(),
                line: toks[i].line,
                msg: "crate/module-wide `#![allow(..)]` hides future violations; \
                      scope the allow to the specific item"
                    .to_string(),
            });
            continue;
        }
        // Item-level: flag only the blanket classes.
        let close = match matching_close(toks, open) {
            Some(c) => c,
            None => continue,
        };
        let content = &toks[open + 1..close];
        let has = |name: &str| content.iter().any(|t| t.kind == TokKind::Ident && t.text == name);
        let blanket = has("warnings")
            || has("dead_code")
            || has("unused")
            || (has("clippy") && has("all"));
        if blanket {
            out.push(Finding {
                rule: NO_BLANKET_ALLOW,
                file: f.path.clone(),
                line: toks[i].line,
                msg: "blanket `#[allow(warnings|unused|dead_code|clippy::all)]` defeats the \
                      `-D warnings` CI gate; allow the one specific lint instead"
                    .to_string(),
            });
        }
        // The tracked `too_many_arguments` allows were all retired via
        // params-struct refactors (AdminCtx / IvfParams / PqShape); new
        // ones are rejected — bundle the arguments instead.
        if has("too_many_arguments") {
            out.push(Finding {
                rule: NO_BLANKET_ALLOW,
                file: f.path.clone(),
                line: toks[i].line,
                msg: "`#[allow(clippy::too_many_arguments)]` is retired in this tree; \
                      group the parameters into a context/params struct instead"
                    .to_string(),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// rule: metric-docs-sync
// ---------------------------------------------------------------------------

const METRIC_CONSTS_FILE: &str = "telemetry/registry.rs";
const METRIC_DOCS_FILE: &str = "coordinator/mod.rs";

/// `pub const NAME: &str = "opdr_…";` declarations, as (value, line).
fn metric_name_consts(f: &SourceFile) -> Vec<(String, usize)> {
    let toks = f.toks();
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if is_ident(toks.get(i), "const")
            && toks.get(i + 1).map(|t| t.kind == TokKind::Ident).unwrap_or(false)
            && is_punct(toks.get(i + 2), ":")
            && is_punct(toks.get(i + 3), "&")
            && is_ident(toks.get(i + 4), "str")
            && is_punct(toks.get(i + 5), "=")
        {
            if let Some(t) = toks.get(i + 6) {
                if t.kind == TokKind::Str && t.text.starts_with("opdr_") {
                    out.push((t.text.clone(), t.line));
                }
            }
        }
    }
    out
}

/// First `` `cell` `` of each `//! | … |` table row, as (cell, line).
fn doc_table_cells(f: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for c in &f.lexed.comments {
        if !c.text.starts_with("//!") {
            continue;
        }
        let body = c.text.trim_start_matches("//!").trim();
        if !body.starts_with('|') {
            continue;
        }
        if let Some(cell) = backticked(body) {
            out.push((cell, c.line));
        }
    }
    out
}

/// Contents of the first `` `…` `` span in `s`.
fn backticked(s: &str) -> Option<String> {
    let start = s.find('`')? + 1;
    let len = s[start..].find('`')?;
    Some(s[start..start + len].to_string())
}

/// Strip a `{label,..}` suffix: docs rows show `opdr_x{worker}`, constants
/// hold the bare family name.
fn metric_family(cell: &str) -> &str {
    cell.split('{').next().unwrap_or(cell)
}

fn metric_docs_sync(files: &[SourceFile]) -> Vec<Finding> {
    let consts_file = files.iter().find(|f| f.norm.ends_with(METRIC_CONSTS_FILE));
    let docs_file = files.iter().find(|f| f.norm.ends_with(METRIC_DOCS_FILE));
    if consts_file.is_none() && docs_file.is_none() {
        return Vec::new(); // corpus doesn't contain the telemetry layer
    }
    let consts = consts_file.map(metric_name_consts).unwrap_or_default();
    let rows: Vec<(String, usize)> = docs_file
        .map(|f| {
            doc_table_cells(f)
                .into_iter()
                .filter(|(c, _)| c.starts_with("opdr_"))
                .map(|(c, l)| (metric_family(&c).to_string(), l))
                .collect()
        })
        .unwrap_or_default();

    let const_names: BTreeSet<&str> = consts.iter().map(|(n, _)| n.as_str()).collect();
    let row_names: BTreeSet<&str> = rows.iter().map(|(n, _)| n.as_str()).collect();

    let mut out = Vec::new();
    for (name, line) in &consts {
        if !row_names.contains(name.as_str()) {
            out.push(Finding {
                rule: METRIC_DOCS_SYNC,
                file: consts_file.unwrap().path.clone(),
                line: *line,
                msg: format!(
                    "metric `{name}` has no row in the {METRIC_DOCS_FILE} module-docs \
                     metrics table (PR 6/8 keep the table authoritative)"
                ),
            });
        }
    }
    for (name, line) in &rows {
        if !const_names.contains(name.as_str()) {
            out.push(Finding {
                rule: METRIC_DOCS_SYNC,
                file: docs_file.unwrap().path.clone(),
                line: *line,
                msg: format!(
                    "documented metric `{name}` has no name constant in \
                     {METRIC_CONSTS_FILE}; remove the row or add the constant"
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// rule: config-docs-sync
// ---------------------------------------------------------------------------

const CONFIG_FILE: &str = "config/schema.rs";

/// `[serve]`/`[dist]` keys accepted by the parser: string-literal match arms
/// whose arm body assigns into `cfg`. The arms live after the
/// `get_path("serve")` / `get_path("dist")` section markers, which is how a
/// key is attributed to its table.
fn config_code_keys(f: &SourceFile) -> BTreeMap<&'static str, Vec<(String, usize)>> {
    let toks = f.toks();
    let first_str = |s: &str| {
        toks.iter().position(|t| t.kind == TokKind::Str && t.text == s).unwrap_or(usize::MAX)
    };
    let serve_at = first_str("serve");
    let dist_at = first_str("dist");
    let mut out: BTreeMap<&'static str, Vec<(String, usize)>> = BTreeMap::new();
    for i in 0..toks.len() {
        let t = match toks.get(i) {
            Some(t) if t.kind == TokKind::Str => t,
            _ => continue,
        };
        if !(is_punct(toks.get(i + 1), "=") && is_punct(toks.get(i + 2), ">")) {
            continue; // not a match arm
        }
        let section = if dist_at != usize::MAX && i > dist_at {
            "dist"
        } else if serve_at != usize::MAX && i > serve_at {
            "serve"
        } else {
            continue;
        };
        if arm_body_mentions(toks, i + 3, "cfg") {
            out.entry(section).or_default().push((t.text.clone(), t.line));
        }
    }
    out
}

/// Does the match-arm body starting at `start` (just past `=>`) contain the
/// identifier `name`? The body is either a braced block or an expression
/// running to the next top-level `,` (or the `}` closing the match).
fn arm_body_mentions(toks: &[Tok], start: usize, name: &str) -> bool {
    if is_punct(toks.get(start), "{") {
        if let Some(close) = matching_close(toks, start) {
            return toks[start..close].iter().any(|t| t.kind == TokKind::Ident && t.text == name);
        }
        return false;
    }
    let mut depth = 0isize;
    for t in toks.iter().skip(start) {
        depth += depth_delta(t);
        if depth < 0 || (depth == 0 && t.kind == TokKind::Punct && t.text == ",") {
            return false;
        }
        if depth >= 0 && t.kind == TokKind::Ident && t.text == name {
            return true;
        }
    }
    false
}

/// Keys documented in the module docs: `//! | `key` | …` rows, sectioned by
/// the nearest preceding `[serve]` / `[dist]` heading line.
fn config_doc_keys(f: &SourceFile) -> BTreeMap<&'static str, Vec<(String, usize)>> {
    let mut out: BTreeMap<&'static str, Vec<(String, usize)>> = BTreeMap::new();
    let mut section: Option<&'static str> = None;
    for c in &f.lexed.comments {
        if !c.text.starts_with("//!") {
            continue;
        }
        let body = c.text.trim_start_matches("//!").trim();
        if body.contains("[serve]") {
            section = Some("serve");
        } else if body.contains("[dist]") {
            section = Some("dist");
        }
        if let (Some(sec), true) = (section, body.starts_with('|')) {
            if let Some(cell) = backticked(body) {
                out.entry(sec).or_default().push((cell, c.line));
            }
        }
    }
    out
}

fn config_docs_sync(files: &[SourceFile]) -> Vec<Finding> {
    let f = match files.iter().find(|f| f.norm.ends_with(CONFIG_FILE)) {
        Some(f) => f,
        None => return Vec::new(),
    };
    let code = config_code_keys(f);
    let docs = config_doc_keys(f);
    let mut out = Vec::new();
    for section in ["serve", "dist"] {
        let code_keys = code.get(section).cloned().unwrap_or_default();
        let doc_keys = docs.get(section).cloned().unwrap_or_default();
        let code_set: BTreeSet<&str> = code_keys.iter().map(|(k, _)| k.as_str()).collect();
        let doc_set: BTreeSet<&str> = doc_keys.iter().map(|(k, _)| k.as_str()).collect();
        for (key, line) in &code_keys {
            if !doc_set.contains(key.as_str()) {
                out.push(Finding {
                    rule: CONFIG_DOCS_SYNC,
                    file: f.path.clone(),
                    line: *line,
                    msg: format!(
                        "`[{section}]` key `{key}` is accepted by the parser but missing \
                         from the module-docs key table"
                    ),
                });
            }
        }
        for (key, line) in &doc_keys {
            if !code_set.contains(key.as_str()) {
                out.push(Finding {
                    rule: CONFIG_DOCS_SYNC,
                    file: f.path.clone(),
                    line: *line,
                    msg: format!(
                        "`[{section}]` key `{key}` is documented but not accepted by the \
                         parser; remove the row or wire the key"
                    ),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(path: &str, src: &str) -> Vec<Finding> {
        lint_sources(&[(PathBuf::from(path), src.to_string())])
    }

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn partial_cmp_unwrap_fires_and_total_cmp_is_clean() {
        let bad = "fn f(xs: &mut [f32]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        let f = run_one("src/knn/topk.rs", bad);
        assert_eq!(rules_of(&f), [NO_PARTIAL_CMP_ORDERING]);
        assert_eq!(f[0].line, 1);

        let bad2 = "let o = x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal);";
        assert_eq!(rules_of(&run_one("src/a.rs", bad2)), [NO_PARTIAL_CMP_ORDERING]);

        let good = "fn f(xs: &mut [f32]) { xs.sort_by(|a, b| a.total_cmp(b)); }";
        assert!(run_one("src/a.rs", good).is_empty());

        // A PartialOrd *impl* delegating to cmp must not fire.
        let impl_ok = "impl PartialOrd for T { fn partial_cmp(&self, o: &T) -> Option<Ordering> { Some(self.cmp(o)) } }";
        assert!(run_one("src/a.rs", impl_ok).is_empty());

        // Checked use without unwrap is fine.
        let checked = "if let Some(o) = a.partial_cmp(&b) { use_it(o); }";
        assert!(run_one("src/a.rs", checked).is_empty());
    }

    #[test]
    fn lock_unwrap_fires_and_lock_recover_is_clean() {
        let bad = "let g = m.lock().unwrap();";
        let f = run_one("src/x.rs", bad);
        assert_eq!(rules_of(&f), [NO_NAKED_LOCK_UNWRAP]);

        let bad_expect = "let g = m.lock().expect(\"poisoned\");";
        assert_eq!(rules_of(&run_one("src/x.rs", bad_expect)), [NO_NAKED_LOCK_UNWRAP]);

        let good = "let g = lock_recover(&m);";
        assert!(run_one("src/x.rs", good).is_empty());

        // The lock_recover implementation itself uses unwrap_or_else: clean.
        let implem = "m.lock().unwrap_or_else(|p| p.into_inner())";
        assert!(run_one("src/x.rs", implem).is_empty());

        // Mentions inside strings and comments never fire.
        let quoted = "// m.lock().unwrap() is forbidden\nlet s = \"m.lock().unwrap()\";";
        assert!(run_one("src/x.rs", quoted).is_empty());
    }

    #[test]
    fn bounded_prealloc_scoped_to_decode_paths() {
        let bad = "let n = read_u32(r)? as usize; let mut buf = vec![0u8; n];";
        let f = run_one("rust/src/data/store.rs", bad);
        assert_eq!(rules_of(&f), [BOUNDED_PREALLOC]);

        let bad_cap = "let mut v = Vec::with_capacity(header.body_len);";
        assert_eq!(rules_of(&run_one("rust/src/rpc/frame.rs", bad_cap)), [BOUNDED_PREALLOC]);

        // Clamped through ALLOC_CHUNK: clean.
        let good = "let mut v = Vec::with_capacity(n.min(ALLOC_CHUNK));";
        assert!(run_one("rust/src/data/store.rs", good).is_empty());

        // Literal / const-only sizes: clean.
        let lit = "let r = BufReader::with_capacity(1 << 20, f); let z = vec![0u8; 64];";
        assert!(run_one("rust/src/data/mapped.rs", lit).is_empty());

        // Same code outside the decode-path scope: not this rule's business.
        let elsewhere = "let mut buf = vec![0u8; n];";
        assert!(run_one("rust/src/knn/topk.rs", elsewhere).is_empty());
    }

    #[test]
    fn unsafe_requires_nearby_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 { unsafe { *p } }";
        let f = run_one("src/x.rs", bad);
        assert_eq!(rules_of(&f), [UNSAFE_NEEDS_SAFETY_COMMENT]);

        let good = "// SAFETY: p is valid for reads by contract.\nfn f(p: *const u8) -> u8 { unsafe { *p } }";
        assert!(run_one("src/x.rs", good).is_empty());

        // A SAFETY comment too far above does not count.
        let far = format!("// SAFETY: stale\n{}unsafe fn g() {{}}", "\n".repeat(SAFETY_WINDOW + 1));
        assert_eq!(rules_of(&run_one("src/x.rs", &far)), [UNSAFE_NEEDS_SAFETY_COMMENT]);

        // `unsafe` in a doc comment or string is not code.
        let quoted = "//! unsafe is discussed here\nlet s = \"unsafe\";";
        assert!(run_one("src/x.rs", quoted).is_empty());
    }

    #[test]
    fn blanket_allow_fires_but_scoped_allow_is_clean() {
        assert_eq!(
            rules_of(&run_one("src/lib.rs", "#![allow(dead_code)]\nfn f() {}")),
            [NO_BLANKET_ALLOW]
        );
        assert_eq!(
            rules_of(&run_one("src/x.rs", "#[allow(clippy::all)]\nfn f() {}")),
            [NO_BLANKET_ALLOW]
        );
        assert_eq!(
            rules_of(&run_one("src/x.rs", "#[allow(warnings)]\nfn f() {}")),
            [NO_BLANKET_ALLOW]
        );
        // The retired-lint class: every tracked `too_many_arguments` allow
        // was removed via params-struct refactors, and new ones are rejected.
        assert_eq!(
            rules_of(&run_one(
                "src/x.rs",
                "#[allow(clippy::too_many_arguments)]\nfn f(a: u8, b: u8) {}"
            )),
            [NO_BLANKET_ALLOW]
        );
        // Other item-scoped allows stay clean.
        let scoped = "#[allow(clippy::needless_range_loop)]\nfn f(a: u8, b: u8) {}";
        assert!(run_one("src/x.rs", scoped).is_empty());
    }

    #[test]
    fn escape_hatch_suppresses_on_same_and_next_two_lines() {
        let same_line = "let g = m.lock().unwrap(); // lint:allow(no-naked-lock-unwrap: test poisons deliberately)";
        assert!(run_one("src/x.rs", same_line).is_empty());

        let above = "// lint:allow(no-naked-lock-unwrap)\nlet g = m.lock().unwrap();";
        assert!(run_one("src/x.rs", above).is_empty());

        // The allow is rule-specific: a different rule's allow does not help.
        let wrong_rule = "// lint:allow(bounded-prealloc)\nlet g = m.lock().unwrap();";
        assert_eq!(rules_of(&run_one("src/x.rs", wrong_rule)), [NO_NAKED_LOCK_UNWRAP]);

        // And it has a bounded reach: three lines above is too far.
        let too_far = "// lint:allow(no-naked-lock-unwrap)\n\n\nlet g = m.lock().unwrap();";
        assert_eq!(rules_of(&run_one("src/x.rs", too_far)), [NO_NAKED_LOCK_UNWRAP]);
    }

    #[test]
    fn metric_docs_sync_both_directions() {
        let registry = r#"
            pub const REQUESTS_TOTAL: &str = "opdr_requests_total";
            pub const ERRORS_TOTAL: &str = "opdr_errors_total";
        "#;
        let docs_ok = "//! | `opdr_requests_total` | counter | requests |\n//! | `opdr_errors_total{kind}` | counter | errors |\n";
        let clean = lint_sources(&[
            (PathBuf::from("src/telemetry/registry.rs"), registry.to_string()),
            (PathBuf::from("src/coordinator/mod.rs"), docs_ok.to_string()),
        ]);
        assert!(clean.is_empty(), "expected clean, got {clean:?}");

        // Constant missing from the table -> flagged at the constant.
        let docs_missing = "//! | `opdr_requests_total` | counter | requests |\n";
        let f = lint_sources(&[
            (PathBuf::from("src/telemetry/registry.rs"), registry.to_string()),
            (PathBuf::from("src/coordinator/mod.rs"), docs_missing.to_string()),
        ]);
        assert_eq!(rules_of(&f), [METRIC_DOCS_SYNC]);
        assert!(f[0].file.ends_with("registry.rs"));
        assert!(f[0].msg.contains("opdr_errors_total"));

        // Table row without a constant -> flagged at the row.
        let docs_extra = "//! | `opdr_requests_total` | c | r |\n//! | `opdr_errors_total` | c | e |\n//! | `opdr_ghost` | g | gone |\n";
        let f = lint_sources(&[
            (PathBuf::from("src/telemetry/registry.rs"), registry.to_string()),
            (PathBuf::from("src/coordinator/mod.rs"), docs_extra.to_string()),
        ]);
        assert_eq!(rules_of(&f), [METRIC_DOCS_SYNC]);
        assert!(f[0].file.ends_with("mod.rs"));
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn config_docs_sync_sections_and_both_directions() {
        let schema_ok = r#"//! Config schema.
//!
//! `[serve]` keys:
//!
//! | key | meaning |
//! |-----|---------|
//! | `workers` | pool size |
//!
//! `[dist]` keys:
//!
//! | key | meaning |
//! |-----|---------|
//! | `listen` | bind address |

fn parse(root: &Value) -> ServeConfig {
    let t = root.get_path("serve");
    for (key, val) in t {
        match key.as_str() {
            "workers" => cfg.workers = pos_int(val),
            other => panic!("unknown {other}"),
        }
    }
    let t = root.get_path("dist");
    for (key, val) in t {
        match key.as_str() {
            "listen" => cfg.listen = val.to_string(),
            other => panic!("unknown {other}"),
        }
    }
    cfg
}
"#;
        assert!(run_one("rust/src/config/schema.rs", schema_ok).is_empty());

        // Key accepted by the parser but undocumented -> flagged at the arm.
        let undocumented = schema_ok.replace(
            "\"workers\" => cfg.workers = pos_int(val),",
            "\"workers\" => cfg.workers = pos_int(val),\n            \"burst\" => cfg.burst = pos_int(val),",
        );
        let f = run_one("rust/src/config/schema.rs", &undocumented);
        assert_eq!(rules_of(&f), [CONFIG_DOCS_SYNC]);
        assert!(f[0].msg.contains("`burst`"));
        assert!(f[0].msg.contains("[serve]"));

        // Documented key the parser rejects -> flagged at the row.
        let ghost_row =
            schema_ok.replace("//! | `listen` | bind address |", "//! | `listen` | bind address |\n//! | `ghost` | gone |");
        let f = run_one("rust/src/config/schema.rs", &ghost_row);
        assert_eq!(rules_of(&f), [CONFIG_DOCS_SYNC]);
        assert!(f[0].msg.contains("`ghost`"));
        assert!(f[0].msg.contains("[dist]"));

        // Same key name in both sections stays section-scoped: documenting
        // `workers` under [serve] does not cover a [dist] `workers` arm.
        let dist_workers = schema_ok.replace(
            "\"listen\" => cfg.listen = val.to_string(),",
            "\"listen\" => cfg.listen = val.to_string(),\n            \"workers\" => cfg.workers = pos_int(val),",
        );
        let f = run_one("rust/src/config/schema.rs", &dist_workers);
        assert_eq!(rules_of(&f), [CONFIG_DOCS_SYNC]);
        assert!(f[0].msg.contains("[dist]"));
        assert!(f[0].msg.contains("`workers`"));

        // Match arms that don't assign into cfg (value enums) are not keys.
        let value_arm = schema_ok.replace(
            "\"workers\" => cfg.workers = pos_int(val),",
            "\"workers\" => cfg.workers = match val.as_str() { \"ram\" => 1, \"mmap\" => 2, _ => 0 },",
        );
        assert!(run_one("rust/src/config/schema.rs", &value_arm).is_empty());
    }

    #[test]
    fn findings_are_sorted_and_display_with_file_line_rule() {
        let src = "let a = m.lock().unwrap();\nlet b = x.partial_cmp(&y).unwrap();";
        let f = run_one("src/z.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f[0].line <= f[1].line);
        let shown = f[0].to_string();
        assert!(shown.contains("src/z.rs:1:"), "{shown}");
        assert!(shown.contains("[no-naked-lock-unwrap]"), "{shown}");
    }
}
