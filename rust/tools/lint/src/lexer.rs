//! A minimal token-level Rust lexer.
//!
//! `opdr-lint` must build offline with zero registry dependencies (like the
//! vendored `xla` stub), so it cannot use `syn`. The rules it enforces are
//! all expressible over a token stream — method-call chains, attribute
//! shapes, match arms, string-literal constants — so a full parse is not
//! needed. What *is* needed, and what a grep-based checker cannot provide,
//! is correct handling of comments, string/char literals, raw strings, and
//! lifetimes, so that a forbidden pattern inside a doc comment or a test
//! fixture string never fires and a `// SAFETY:` comment is reliably
//! distinguished from code.
//!
//! The lexer produces two streams: code tokens (with the comments stripped)
//! and the comments themselves, both carrying 1-based line numbers. Rules
//! match on the token stream and consult the comment stream for `SAFETY:`
//! annotations and `lint:allow(..)` escape hatches.

/// Kinds of code tokens. Comments are reported separately (see [`Comment`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (the lexer does not distinguish them).
    Ident,
    /// Lifetime such as `'a` or `'static` (leading `'` included in text).
    Lifetime,
    /// Integer or float literal, including suffix (`1_000`, `1.5e-3f32`).
    Number,
    /// String literal of any flavour (`"…"`, `r#"…"#`, `b"…"`), with the
    /// text field holding the *unquoted* contents (escapes left as written).
    Str,
    /// Character or byte literal (`'x'`, `b'\n'`), quotes stripped.
    Char,
    /// A single punctuation character (`.`, `(`, `=`, `>`, …). Multi-char
    /// operators arrive as consecutive tokens; rules that care check
    /// adjacency, which is sufficient for valid Rust input.
    Punct,
}

/// One code token.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line on which the token starts.
    pub line: usize,
}

/// One comment (line or block), reported out-of-band from the code tokens.
#[derive(Debug, Clone)]
pub struct Comment {
    /// Full comment text including the `//` / `/*` markers.
    pub text: String,
    /// 1-based line on which the comment starts.
    pub line: usize,
}

/// Lexer output: code tokens plus retained comments.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Tokenize `src`. Never fails: unterminated literals are closed at EOF so
/// the linter degrades gracefully on malformed input instead of panicking.
pub fn lex(src: &str) -> Lexed {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Lexed::default() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: usize,
    out: Lexed,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, kind: TokKind, text: String, line: usize) {
        self.out.tokens.push(Tok { kind, text, line });
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            match c {
                c if c.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(line),
                '/' if self.peek(1) == Some('*') => self.block_comment(line),
                '"' => {
                    self.bump();
                    self.quoted_string(line);
                }
                'r' | 'b' => self.ident_or_prefixed_literal(line),
                '\'' => self.char_or_lifetime(line),
                c if c.is_alphabetic() || c == '_' => self.ident(line),
                c if c.is_ascii_digit() => self.number(line),
                _ => {
                    self.bump();
                    self.push(TokKind::Punct, c.to_string(), line);
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.comments.push(Comment { text, line });
    }

    fn block_comment(&mut self, line: usize) {
        let mut text = String::new();
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                text.push_str("*/");
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.out.comments.push(Comment { text, line });
    }

    /// Consume a `"…"` body; the opening quote is already consumed.
    fn quoted_string(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.bump() {
            match c {
                '"' => break,
                '\\' => {
                    text.push(c);
                    if let Some(esc) = self.bump() {
                        text.push(esc);
                    }
                }
                _ => text.push(c),
            }
        }
        self.push(TokKind::Str, text, line);
    }

    /// `r` / `b` can start raw strings (`r"`, `r#"`), byte strings (`b"`,
    /// `br"`), byte chars (`b'`), raw identifiers (`r#ident`), or a plain
    /// identifier. Disambiguate by lookahead.
    fn ident_or_prefixed_literal(&mut self, line: usize) {
        let c0 = self.peek(0).unwrap();
        // Raw string prefixes: r"  r#"  br"  br#"  (and b" / b' handled below)
        let (raw_at, is_raw) = match (c0, self.peek(1)) {
            ('r', Some('"')) | ('r', Some('#')) => (1, true),
            ('b', Some('r')) if matches!(self.peek(2), Some('"') | Some('#')) => (2, true),
            _ => (0, false),
        };
        if is_raw {
            // Count `#`s after the prefix; raw string iff they end in `"`.
            let mut hashes = 0;
            while self.peek(raw_at + hashes) == Some('#') {
                hashes += 1;
            }
            if self.peek(raw_at + hashes) == Some('"') {
                for _ in 0..raw_at + hashes + 1 {
                    self.bump();
                }
                self.raw_string_body(hashes, line);
                return;
            }
            // `r#ident` raw identifier falls through to ident lexing below.
        }
        if c0 == 'b' && self.peek(1) == Some('"') {
            self.bump();
            self.bump();
            self.quoted_string(line);
            return;
        }
        if c0 == 'b' && self.peek(1) == Some('\'') {
            self.bump();
            self.bump();
            self.char_body(line);
            return;
        }
        self.ident(line);
    }

    fn raw_string_body(&mut self, hashes: usize, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.bump() {
            if c == '"' {
                let mut n = 0;
                while n < hashes && self.peek(n) == Some('#') {
                    n += 1;
                }
                if n == hashes {
                    for _ in 0..hashes {
                        self.bump();
                    }
                    break;
                }
            }
            text.push(c);
        }
        self.push(TokKind::Str, text, line);
    }

    /// `'` starts either a lifetime (`'a`, `'static`) or a char literal
    /// (`'x'`, `'\n'`). Lifetime iff the next char starts an identifier and
    /// the char after it is not a closing `'`.
    fn char_or_lifetime(&mut self, line: usize) {
        let next = self.peek(1);
        let is_lifetime = matches!(next, Some(c) if c.is_alphabetic() || c == '_')
            && self.peek(2) != Some('\'');
        self.bump(); // the `'`
        if is_lifetime {
            let mut text = String::from("'");
            while let Some(c) = self.peek(0) {
                if c.is_alphanumeric() || c == '_' {
                    text.push(c);
                    self.bump();
                } else {
                    break;
                }
            }
            self.push(TokKind::Lifetime, text, line);
        } else {
            self.char_body(line);
        }
    }

    /// Consume a char-literal body; the opening `'` is already consumed.
    fn char_body(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.bump() {
            match c {
                '\'' => break,
                '\\' => {
                    text.push(c);
                    if let Some(esc) = self.bump() {
                        text.push(esc);
                    }
                }
                _ => text.push(c),
            }
        }
        self.push(TokKind::Char, text, line);
    }

    fn ident(&mut self, line: usize) {
        let mut text = String::new();
        // Accept the `r#` of raw identifiers, then ident chars.
        if self.peek(0) == Some('r') && self.peek(1) == Some('#') {
            self.bump();
            self.bump();
        }
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokKind::Ident, text, line);
    }

    fn number(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else if c == '.' {
                // Consume the dot only when a fractional digit follows, so
                // `0.partial_cmp`, `0..n`, and tuple indices stay separate
                // tokens while `1.5` stays one.
                match self.peek(1) {
                    Some(d) if d.is_ascii_digit() => {
                        text.push(c);
                        self.bump();
                    }
                    _ => break,
                }
            } else if (c == '+' || c == '-')
                && matches!(text.chars().last(), Some('e') | Some('E'))
            {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokKind::Number, text, line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).tokens.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_are_stripped_and_retained() {
        let l = lex("a // trailing\n/* block\nspans */ b");
        let idents: Vec<_> = l.tokens.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(idents, ["a", "b"]);
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 2);
        assert!(l.comments[1].text.contains("spans"));
        assert_eq!(l.tokens[1].line, 3);
    }

    #[test]
    fn nested_block_comment() {
        let l = lex("/* outer /* inner */ still comment */ x");
        assert_eq!(l.tokens.len(), 1);
        assert_eq!(l.tokens[0].text, "x");
    }

    #[test]
    fn strings_hide_code_like_content() {
        let l = lex(r#"let s = "a.lock().unwrap() // not a comment";"#);
        assert_eq!(l.comments.len(), 0);
        let strs: Vec<_> =
            l.tokens.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.clone()).collect();
        assert_eq!(strs, ["a.lock().unwrap() // not a comment"]);
    }

    #[test]
    fn raw_and_byte_strings() {
        let l = lex(r###"let a = r#"raw "quoted" body"#; let b = b"bytes"; let c = br"rb";"###);
        let strs: Vec<_> =
            l.tokens.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.clone()).collect();
        assert_eq!(strs, [r#"raw "quoted" body"#, "bytes", "rb"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; let s: &'static str; }");
        let lifetimes: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokKind::Lifetime).map(|(_, t)| t.clone()).collect();
        assert_eq!(lifetimes, ["'a", "'a", "'static"]);
        let chars: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokKind::Char).map(|(_, t)| t.clone()).collect();
        assert_eq!(chars, ["x", "\\n"]);
    }

    #[test]
    fn numbers_do_not_swallow_method_calls() {
        let toks = kinds("self.0.partial_cmp(&x); 1.5e-3f32; 0..n; vec![0u8; 64]");
        let texts: Vec<_> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert!(texts.contains(&"partial_cmp"));
        assert!(texts.contains(&"1.5e-3f32"));
        assert!(texts.contains(&"0u8"));
        // `0..n` lexes as number, dot, dot, ident.
        let i = texts.iter().position(|t| *t == "0").unwrap();
        assert_eq!(texts[i + 1], ".");
        assert_eq!(texts[i + 2], ".");
        assert_eq!(texts[i + 3], "n");
    }

    #[test]
    fn raw_identifiers() {
        let toks = kinds("let r#type = 1;");
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "type"));
    }

    #[test]
    fn line_numbers_are_one_based_and_track_newlines() {
        let l = lex("a\nb\n\nc");
        let lines: Vec<_> = l.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, [1, 2, 4]);
    }
}
