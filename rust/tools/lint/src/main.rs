//! CLI for `opdr-lint`. Usage:
//!
//! ```text
//! opdr-lint [--list-rules] [PATH ...]
//! ```
//!
//! With no paths, lints the repo's default scope — `rust/src`, `rust/tests`,
//! `rust/benches` — resolved against the current directory (also works when
//! invoked from inside `rust/`). Exits non-zero when any rule fires; every
//! finding is printed as `file:line: [rule] message`.

use std::path::PathBuf;
use std::process::ExitCode;

fn default_scope() -> Vec<PathBuf> {
    let roots = ["rust/src", "rust/tests", "rust/benches"];
    let here: Vec<PathBuf> = roots.iter().map(PathBuf::from).collect();
    if here[0].is_dir() {
        return here;
    }
    // Invoked from inside rust/ (e.g. `cargo run` with rust/ as cwd).
    let nested: Vec<PathBuf> = ["src", "tests", "benches"].iter().map(PathBuf::from).collect();
    if nested[0].is_dir() {
        return nested;
    }
    here
}

fn main() -> ExitCode {
    let mut paths: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--list-rules" => {
                for (name, summary) in opdr_lint::RULES {
                    println!("{name}: {summary}");
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!("usage: opdr-lint [--list-rules] [PATH ...]");
                println!("lints PATHs (default: rust/src rust/tests rust/benches);");
                println!("exits 1 if any repo-invariant rule fires.");
                return ExitCode::SUCCESS;
            }
            _ => paths.push(PathBuf::from(arg)),
        }
    }
    if paths.is_empty() {
        paths = default_scope();
    }
    // Tolerate a missing bench/test dir, but not a typoed explicit path.
    let existing: Vec<PathBuf> = paths.iter().filter(|p| p.exists()).cloned().collect();
    if existing.is_empty() {
        eprintln!("opdr-lint: no such paths: {paths:?}");
        return ExitCode::FAILURE;
    }
    for missing in paths.iter().filter(|p| !p.exists()) {
        eprintln!("opdr-lint: warning: skipping missing path {}", missing.display());
    }

    match opdr_lint::lint_paths(&existing) {
        Ok(findings) if findings.is_empty() => {
            println!("opdr-lint: clean ({} rules)", opdr_lint::RULES.len());
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("opdr-lint: {} violation(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("opdr-lint: io error: {e}");
            ExitCode::FAILURE
        }
    }
}
