//! CLI for `opdr-lint`. Usage:
//!
//! ```text
//! opdr-lint [--list-rules] [PATH ...]
//! opdr-lint analyze [PATH ...]
//! ```
//!
//! With no paths, the default lint scope is `rust/src`, `rust/tests`,
//! `rust/benches` resolved against the current directory (also works when
//! invoked from inside `rust/`). `analyze` runs the concurrency pass
//! (lock-order, rank-table-sync, atomic-ordering, unbounded-channel); its
//! default scope is `rust/src` only — the test suites deliberately
//! construct inversions and poisonings for the runtime sentinel to catch.
//! Exits non-zero when any rule fires; every finding is printed as
//! `file:line: [rule] message`.

use std::path::PathBuf;
use std::process::ExitCode;

fn default_scope() -> Vec<PathBuf> {
    let roots = ["rust/src", "rust/tests", "rust/benches"];
    let here: Vec<PathBuf> = roots.iter().map(PathBuf::from).collect();
    if here[0].is_dir() {
        return here;
    }
    // Invoked from inside rust/ (e.g. `cargo run` with rust/ as cwd).
    let nested: Vec<PathBuf> = ["src", "tests", "benches"].iter().map(PathBuf::from).collect();
    if nested[0].is_dir() {
        return nested;
    }
    here
}

fn analyze_scope() -> Vec<PathBuf> {
    let here = PathBuf::from("rust/src");
    if here.is_dir() {
        return vec![here];
    }
    let nested = PathBuf::from("src");
    if nested.is_dir() {
        return vec![nested];
    }
    vec![here]
}

fn main() -> ExitCode {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut analyze = false;
    for (i, arg) in std::env::args().skip(1).enumerate() {
        match arg.as_str() {
            "analyze" if i == 0 => analyze = true,
            "--list-rules" => {
                for (name, summary) in opdr_lint::RULES {
                    println!("{name}: {summary}");
                }
                for (name, summary) in opdr_lint::ANALYZE_RULES {
                    println!("{name}: {summary} (via `opdr-lint analyze`)");
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!("usage: opdr-lint [--list-rules] [PATH ...]");
                println!("       opdr-lint analyze [PATH ...]");
                println!("lints PATHs (default: rust/src rust/tests rust/benches);");
                println!("`analyze` runs the concurrency pass (default: rust/src);");
                println!("exits 1 if any repo-invariant rule fires.");
                return ExitCode::SUCCESS;
            }
            _ => paths.push(PathBuf::from(arg)),
        }
    }
    if paths.is_empty() {
        paths = if analyze { analyze_scope() } else { default_scope() };
    }
    // Tolerate a missing bench/test dir, but not a typoed explicit path.
    let existing: Vec<PathBuf> = paths.iter().filter(|p| p.exists()).cloned().collect();
    if existing.is_empty() {
        eprintln!("opdr-lint: no such paths: {paths:?}");
        return ExitCode::FAILURE;
    }
    for missing in paths.iter().filter(|p| !p.exists()) {
        eprintln!("opdr-lint: warning: skipping missing path {}", missing.display());
    }

    let (result, nrules) = if analyze {
        (opdr_lint::analyze_paths(&existing), opdr_lint::ANALYZE_RULES.len())
    } else {
        (opdr_lint::lint_paths(&existing), opdr_lint::RULES.len())
    };
    match result {
        Ok(findings) if findings.is_empty() => {
            println!("opdr-lint: clean ({nrules} rules)");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("opdr-lint: {} violation(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("opdr-lint: io error: {e}");
            ExitCode::FAILURE
        }
    }
}
