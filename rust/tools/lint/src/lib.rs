//! `opdr-lint`: repo-invariant static analysis for the opdr tree.
//!
//! PRs 1–8 hardened the serving stack by hand: `total_cmp`-only comparators
//! (PR 4/5 NaN sweeps), `ALLOC_CHUNK`-clamped decoder preallocation
//! (PR 5/7), poison-recovering locks (PR 4), `// SAFETY:`-annotated
//! `unsafe` (PR 5 mmap), and docs-synced metric/config tables (PR 6/8).
//! This crate promotes those conventions from reviewer memory to a CI-gated
//! check: a dependency-free, token-level scanner (no `syn` — the workspace
//! builds offline) that walks `rust/src` + `rust/tests` + `rust/benches`
//! and reports named, allowlist-aware rules with `file:line` diagnostics.
//!
//! Library surface:
//! - [`lint_sources`] lints an in-memory corpus (what the fixture tests use);
//! - [`lint_paths`] walks directories/files and lints what it finds
//!   (what the CLI and the live-tree test use);
//! - [`RULES`] names every rule; `// lint:allow(rule: reason)` on the
//!   flagged line or the two lines above it suppresses a finding.

pub mod analyze;
pub mod lexer;
pub mod rules;

pub use analyze::{analyze_sources, ANALYZE_RULES};
pub use rules::{lint_sources, Finding, RULES};

use std::io;
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under each of `paths` (files are taken
/// as-is). `target/` subtrees are skipped. The result is sorted so runs are
/// deterministic.
pub fn collect_rs_files(paths: &[PathBuf]) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for p in paths {
        walk(p, &mut out)?;
    }
    out.sort();
    out.dedup();
    Ok(out)
}

fn walk(p: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = std::fs::metadata(p)?;
    if meta.is_file() {
        if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p.to_path_buf());
        }
        return Ok(());
    }
    if p.file_name().map(|n| n == "target").unwrap_or(false) {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(p)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for e in entries {
        walk(&e, out)?;
    }
    Ok(())
}

/// Walk `paths`, read every `.rs` file, and lint the corpus.
pub fn lint_paths(paths: &[PathBuf]) -> io::Result<Vec<Finding>> {
    let files = collect_rs_files(paths)?;
    let mut corpus = Vec::with_capacity(files.len());
    for f in files {
        let src = std::fs::read_to_string(&f)?;
        corpus.push((f, src));
    }
    Ok(lint_sources(&corpus))
}

/// Walk `paths`, read every `.rs` file, and run the concurrency pass
/// (`opdr-lint analyze`: lock-order, rank-table-sync, atomic-ordering,
/// unbounded-channel) over the corpus.
pub fn analyze_paths(paths: &[PathBuf]) -> io::Result<Vec<Finding>> {
    let files = collect_rs_files(paths)?;
    let mut corpus = Vec::with_capacity(files.len());
    for f in files {
        let src = std::fs::read_to_string(&f)?;
        corpus.push((f, src));
    }
    Ok(analyze_sources(&corpus))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walker_finds_rs_files_and_skips_target() {
        let dir = std::env::temp_dir().join(format!("opdr_lint_walk_{}", std::process::id()));
        let sub = dir.join("src");
        let tgt = dir.join("target");
        std::fs::create_dir_all(&sub).unwrap();
        std::fs::create_dir_all(&tgt).unwrap();
        std::fs::write(sub.join("a.rs"), "fn a() {}").unwrap();
        std::fs::write(sub.join("b.txt"), "not rust").unwrap();
        std::fs::write(tgt.join("gen.rs"), "fn hidden() {}").unwrap();
        let files = collect_rs_files(&[dir.clone()]).unwrap();
        assert_eq!(files, vec![sub.join("a.rs")]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lint_paths_reports_with_real_file_path() {
        let dir = std::env::temp_dir().join(format!("opdr_lint_paths_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.rs");
        std::fs::write(&bad, "fn f() { let g = m.lock().unwrap(); }").unwrap();
        let findings = lint_paths(&[dir.clone()]).unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].file, bad);
        assert_eq!(findings[0].line, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
