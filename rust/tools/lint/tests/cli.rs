//! End-to-end checks of the `opdr-lint` binary: exit codes and diagnostic
//! shape, driven through a real process the way CI invokes it. Library-level
//! rule behavior is covered by the fixture matrix in
//! `rust/tests/lint_it.rs`; this file only pins the CLI contract.

use std::fs;
use std::path::PathBuf;
use std::process::Command;

/// A scratch dir under the system temp root, removed on drop.
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir()
            .join(format!("opdr-lint-cli-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).expect("creating scratch dir");
        Scratch(dir)
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_opdr-lint"))
        .args(args)
        .output()
        .expect("spawning opdr-lint");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn clean_tree_exits_zero() {
    let s = Scratch::new("clean");
    fs::write(
        s.0.join("ok.rs"),
        "fn main() {\n    let xs = [3.0f32, 1.0];\n    let _ = xs[0].total_cmp(&xs[1]);\n}\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&[s.0.to_str().unwrap()]);
    assert!(ok, "clean dir must exit 0; stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("clean"), "summary line missing: {stdout}");
}

#[test]
fn violation_exits_nonzero_with_file_line_diagnostic() {
    let s = Scratch::new("dirty");
    let bad = s.0.join("bad.rs");
    fs::write(
        &bad,
        "fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n",
    )
    .unwrap();
    let (ok, stdout, _) = run(&[s.0.to_str().unwrap()]);
    assert!(!ok, "violations must exit non-zero; stdout={stdout}");
    // CI greps for this exact `file:line: [rule]` shape.
    let want = format!("{}:2: [no-naked-lock-unwrap]", bad.display());
    assert!(stdout.contains(&want), "missing `{want}` in:\n{stdout}");
    assert!(stdout.contains("1 violation"), "summary count missing: {stdout}");
}

#[test]
fn lint_allow_silences_the_cli_too() {
    let s = Scratch::new("allowed");
    fs::write(
        s.0.join("allowed.rs"),
        "// lint:allow(no-naked-lock-unwrap: fixture exercising the escape hatch)\n\
         fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n",
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&[s.0.to_str().unwrap()]);
    assert!(ok, "allowed violation must exit 0; stdout={stdout} stderr={stderr}");
}

#[test]
fn list_rules_names_every_rule() {
    let (ok, stdout, _) = run(&["--list-rules"]);
    assert!(ok);
    for (name, _) in opdr_lint::RULES {
        assert!(stdout.contains(name), "--list-rules missing {name}: {stdout}");
    }
}

#[test]
fn missing_paths_fail_loudly() {
    let s = Scratch::new("missing");
    let ghost = s.0.join("does-not-exist");
    let (ok, _, stderr) = run(&[ghost.to_str().unwrap()]);
    assert!(!ok, "nonexistent explicit path must not silently pass");
    assert!(!stderr.is_empty(), "expected an error message on stderr");
}
