//! Index substrate comparison at the planner-chosen dimension.
//!
//! The paper's serving story is "reduce the dimension first, then index".
//! This bench runs the second half: it calibrates the OPDR planner on a
//! synthetic multimodal set, projects everything to the dimension planned
//! for A=0.9, then compares the pluggable ANN substrates — exact flat scan,
//! IVF-Flat, HNSW and HNSW+SQ8 — on recall@10 against exact KNN, query
//! throughput, build time and resident index bytes.
//!
//! Run: `cargo bench --bench index_substrates`

use opdr::bench_support::{section, Bencher};
use opdr::config::IndexPolicy;
use opdr::coordinator::ThreadPool;
use opdr::data::{synth, DatasetKind};
use opdr::index::{build_index, AnnIndex, IndexKind};
use opdr::knn::knn_indices;
use opdr::metrics::Metric;
use opdr::opdr::Planner;
use opdr::reduction::{Pca, ReducerKind};
use opdr::report::{write_csv, Table};
use opdr::util::Stopwatch;
use std::sync::Arc;

const N: usize = 4000;
const NQ: usize = 200;
const DIM: usize = 256;
const K: usize = 10;
const CALIB: usize = 200;
const METRIC: Metric = Metric::SqEuclidean;

fn recall_at_k(
    idx: &dyn AnnIndex,
    queries: &[f32],
    dim: usize,
    truth: &[Vec<usize>],
) -> f64 {
    let mut hits = 0usize;
    for (qi, want) in truth.iter().enumerate() {
        let q = &queries[qi * dim..(qi + 1) * dim];
        let got: std::collections::HashSet<usize> =
            idx.search(q, K).unwrap().iter().map(|n| n.index).collect();
        hits += want.iter().filter(|i| got.contains(*i)).count();
    }
    hits as f64 / (truth.len() * K) as f64
}

fn main() {
    // CI runs the distributed axis on its own (`-- --dist-only`): it needs
    // no planner calibration and must stay cheap enough for a release-mode
    // gate on every push.
    if std::env::args().any(|a| a == "--dist-only") {
        run_dist_axis();
        return;
    }
    // Same deal for the observability-overhead axis (`-- --obs-only`):
    // tracing + federation enabled vs disabled on an otherwise identical
    // cluster, CI-gated to cost at most 10% QPS.
    if std::env::args().any(|a| a == "--obs-only") {
        run_obs_axis();
        return;
    }
    let set = synth::generate(DatasetKind::Flickr30k, N + NQ, DIM, 42);
    let base_full = &set.data()[..N * DIM];
    let query_full = &set.data()[N * DIM..];

    // Plan the serving dimension the OPDR way: calibrate on a sample, invert
    // the closed form for A=0.9.
    let sample = &base_full[..CALIB * DIM];
    let planner =
        Planner::calibrate(sample, DIM, K, METRIC, ReducerKind::Pca, 7).expect("calibrate");
    // Round the planned dim up to even so the PQ axis gets its headline
    // m = dim/2 (2-dim subspaces) without a divisor fallback.
    let target_dim = planner.dim_for_accuracy(0.9, CALIB).min(DIM);
    let target_dim = ((target_dim + 1) / 2 * 2).clamp(2, DIM);
    let model = Pca::new().fit(sample, DIM, target_dim).expect("pca fit");
    let base = model.project(base_full).expect("project base");
    let queries = model.project(query_full).expect("project queries");
    let dim = target_dim;
    section(&format!(
        "index substrates over {N} vectors at planner-chosen dim {dim} (from {DIM}, A=0.9)"
    ));

    // Exact ground truth in the reduced space.
    let truth: Vec<Vec<usize>> = (0..NQ)
        .map(|qi| {
            knn_indices(&queries[qi * dim..(qi + 1) * dim], &base, dim, K, METRIC)
                .unwrap()
                .into_iter()
                .map(|n| n.index)
                .collect()
        })
        .collect();

    let substrates: Vec<(&str, IndexPolicy)> = vec![
        (
            "exact",
            IndexPolicy { kind: IndexKind::Exact, exact_threshold: 0, ..Default::default() },
        ),
        (
            "ivf",
            IndexPolicy {
                kind: IndexKind::Ivf,
                exact_threshold: 0,
                ivf_nlist: 64,
                ivf_nprobe: 8,
                ..Default::default()
            },
        ),
        (
            "hnsw",
            IndexPolicy { kind: IndexKind::Hnsw, exact_threshold: 0, ..Default::default() },
        ),
        (
            "hnsw-plain",
            IndexPolicy {
                kind: IndexKind::Hnsw,
                exact_threshold: 0,
                hnsw_heuristic: false,
                ..Default::default()
            },
        ),
        (
            "hnsw+sq8",
            IndexPolicy {
                kind: IndexKind::Hnsw,
                exact_threshold: 0,
                sq8: true,
                ..Default::default()
            },
        ),
        (
            "exact+pq",
            IndexPolicy {
                kind: IndexKind::Exact,
                exact_threshold: 0,
                pq: true,
                rerank_depth: 4 * K,
                ..Default::default()
            },
        ),
        (
            "hnsw+pq",
            IndexPolicy {
                kind: IndexKind::Hnsw,
                exact_threshold: 0,
                pq: true,
                rerank_depth: 4 * K,
                ..Default::default()
            },
        ),
    ];

    let bencher = Bencher { warmup_iters: 1, iters: 5, max_time: std::time::Duration::from_secs(30) };
    let mut table =
        Table::new(&["substrate", "build ms", "recall@10", "qps", "index KiB", "quantized"]);
    let mut rows = Vec::new();
    for (name, policy) in &substrates {
        let sw = Stopwatch::start();
        let idx = build_index(&base, dim, METRIC, policy, 9).expect("build index");
        let build_ms = sw.elapsed_ns() / 1e6;

        let recall = recall_at_k(idx.as_ref(), &queries, dim, &truth);
        let r = bencher.run_items(name, NQ as u64, || {
            for qi in 0..NQ {
                let q = &queries[qi * dim..(qi + 1) * dim];
                let out = idx.search(q, K).unwrap();
                std::hint::black_box(out.len());
            }
        });
        let qps = r.throughput().unwrap_or(0.0);
        let kib = idx.memory_bytes() as f64 / 1024.0;
        table.row(&[
            name.to_string(),
            format!("{build_ms:.1}"),
            format!("{recall:.3}"),
            format!("{qps:.0}"),
            format!("{kib:.0}"),
            idx.quantized().to_string(),
        ]);
        rows.push(vec![
            name.to_string(),
            format!("{build_ms}"),
            format!("{recall}"),
            format!("{qps}"),
            format!("{kib}"),
        ]);
    }
    println!("{}", table.render());
    write_csv(
        "bench_out/index_substrates.csv",
        &["substrate", "build_ms", "recall_at_10", "qps", "index_kib"],
        &rows,
    )
    .expect("csv");

    println!(
        "\nreading: exact is the recall ceiling and the QPS floor; IVF trades recall\n\
         for probe-bounded scans; HNSW holds recall near 1.0 at graph-walk cost;\n\
         SQ8 shrinks the resident copy ~4x with a small asymmetric-distance penalty."
    );

    // ---------------------------------------------------------------
    // Shard-count axis: S ∈ {1, 2, 4, 8} — serial vs pool build time,
    // fan-out QPS, recall@10. Results land in BENCH_shards.json.
    // ---------------------------------------------------------------
    let workers = 4usize;
    section(&format!(
        "shard-count axis over {N} vectors at dim {dim}: S in {{1,2,4,8}}, {workers} workers"
    ));
    let pool = ThreadPool::new(workers);
    let base_arc = Arc::new(base.clone());
    let mut shard_table =
        Table::new(&["substrate", "S", "build ms", "pool build ms", "recall@10", "qps"]);
    let mut json_rows: Vec<String> = Vec::new();
    for (name, kind) in [("exact", IndexKind::Exact), ("hnsw", IndexKind::Hnsw)] {
        for s in [1usize, 2, 4, 8] {
            let policy = IndexPolicy {
                kind,
                exact_threshold: 0,
                shards: s,
                shard_min_vectors: 1,
                ..Default::default()
            };
            let sw = Stopwatch::start();
            let idx = build_index(&base, dim, METRIC, &policy, 9).expect("build sharded");
            let build_ms = sw.elapsed_ns() / 1e6;
            assert_eq!(idx.as_sharded().map_or(1, |sh| sh.num_shards()), s);

            let sw = Stopwatch::start();
            let (tx, rx) = std::sync::mpsc::channel();
            opdr::index::shard::build_on_pool(
                Arc::clone(&base_arc),
                dim,
                METRIC,
                &policy,
                9,
                &pool,
                move |r| {
                    let _ = tx.send(r);
                },
            );
            let pooled = rx.recv().expect("collector").expect("pool build");
            let pool_build_ms = sw.elapsed_ns() / 1e6;
            drop(pooled);

            let recall = recall_at_k(idx.as_ref(), &queries, dim, &truth);
            let r = bencher.run_items(&format!("{name} S={s}"), NQ as u64, || {
                for qi in 0..NQ {
                    let q = &queries[qi * dim..(qi + 1) * dim];
                    let out = match idx.as_sharded() {
                        Some(sh) => sh.search_on(&pool, q, K).unwrap(),
                        None => idx.search(q, K).unwrap(),
                    };
                    std::hint::black_box(out.len());
                }
            });
            let qps = r.throughput().unwrap_or(0.0);
            shard_table.row(&[
                name.to_string(),
                s.to_string(),
                format!("{build_ms:.1}"),
                format!("{pool_build_ms:.1}"),
                format!("{recall:.3}"),
                format!("{qps:.0}"),
            ]);
            json_rows.push(format!(
                "{{\"substrate\":\"{name}\",\"shards\":{s},\"build_ms\":{build_ms:.3},\
                 \"pool_build_ms\":{pool_build_ms:.3},\"recall_at_10\":{recall:.4},\
                 \"qps\":{qps:.1}}}"
            ));
        }
    }
    println!("{}", shard_table.render());
    let json = format!(
        "{{\"bench\":\"index_shards\",\"n\":{N},\"dim\":{dim},\"k\":{K},\
         \"pool_workers\":{workers},\"rows\":[\n  {}\n]}}\n",
        json_rows.join(",\n  ")
    );
    std::fs::create_dir_all("bench_out").expect("bench_out dir");
    std::fs::write("bench_out/BENCH_shards.json", json).expect("write BENCH_shards.json");
    println!("wrote bench_out/BENCH_shards.json");

    println!(
        "\nreading: builds parallelize near-linearly in S on the pool (HNSW\n\
         construction dominates); exact fan-out QPS dips at small N (merge\n\
         overhead) and the sharded merge keeps recall pinned to the\n\
         single-segment value for exact — order-exactness costs nothing."
    );

    // ---------------------------------------------------------------
    // Compression axis: flat f32 vs SQ8 vs PQ vs PQ+OPQ — compression
    // ratio × recall@10 × QPS, sweeping the PQ rerank depth. Results
    // land in BENCH_pq.json; the PQ rows must clear the 8× bar.
    // ---------------------------------------------------------------
    section(&format!(
        "compression axis over {N} vectors at dim {dim}: f32 / sq8 / pq(m=dim/2, ksub=16) / pq+opq"
    ));
    let flat_bytes = (N * dim * std::mem::size_of::<f32>()) as f64;
    let mut pq_table = Table::new(&[
        "storage",
        "rerank depth",
        "compression",
        "recall@10",
        "qps",
        "hot KiB",
        "cold KiB",
    ]);
    let mut pq_json: Vec<String> = Vec::new();
    let variants: Vec<(&str, IndexPolicy, usize)> = vec![
        (
            "f32",
            IndexPolicy { kind: IndexKind::Exact, exact_threshold: 0, ..Default::default() },
            0,
        ),
        (
            "sq8",
            IndexPolicy {
                kind: IndexKind::Exact,
                exact_threshold: 0,
                sq8: true,
                ..Default::default()
            },
            0,
        ),
        (
            "pq",
            IndexPolicy {
                kind: IndexKind::Exact,
                exact_threshold: 0,
                pq: true,
                ..Default::default()
            },
            2 * K,
        ),
        (
            "pq",
            IndexPolicy {
                kind: IndexKind::Exact,
                exact_threshold: 0,
                pq: true,
                ..Default::default()
            },
            8 * K,
        ),
        (
            "pq+opq",
            IndexPolicy {
                kind: IndexKind::Exact,
                exact_threshold: 0,
                pq: true,
                pq_opq: true,
                ..Default::default()
            },
            8 * K,
        ),
    ];
    for (name, policy, depth) in variants {
        let policy = if depth > 0 { IndexPolicy { rerank_depth: depth, ..policy } } else { policy };
        let idx = build_index(&base, dim, METRIC, &policy, 9).expect("build compression variant");
        let recall = recall_at_k(idx.as_ref(), &queries, dim, &truth);
        let r = bencher.run_items(&format!("{name} d={depth}"), NQ as u64, || {
            for qi in 0..NQ {
                let q = &queries[qi * dim..(qi + 1) * dim];
                let out = idx.search(q, K).unwrap();
                std::hint::black_box(out.len());
            }
        });
        let qps = r.throughput().unwrap_or(0.0);
        let ratio = flat_bytes / idx.memory_bytes() as f64;
        // Acceptance bar: PQ at m=dim/2 must clear 8× (OPQ's dim² rotation
        // is a constant overhead amortized by n, so it is reported but not
        // gated).
        if name == "pq" {
            assert!(
                ratio >= 8.0,
                "{name}: compression {ratio:.2}x below the 8x acceptance bar"
            );
        }
        pq_table.row(&[
            name.to_string(),
            depth.to_string(),
            format!("{ratio:.1}x"),
            format!("{recall:.3}"),
            format!("{qps:.0}"),
            format!("{:.0}", idx.memory_bytes() as f64 / 1024.0),
            format!("{:.0}", idx.cold_bytes() as f64 / 1024.0),
        ]);
        pq_json.push(format!(
            "{{\"storage\":\"{name}\",\"rerank_depth\":{depth},\"compression\":{ratio:.3},\
             \"recall_at_10\":{recall:.4},\"qps\":{qps:.1},\"hot_bytes\":{},\"cold_bytes\":{}}}",
            idx.memory_bytes(),
            idx.cold_bytes()
        ));
    }
    println!("{}", pq_table.render());
    let json = format!(
        "{{\"bench\":\"index_pq\",\"n\":{N},\"dim\":{dim},\"k\":{K},\"rows\":[\n  {}\n]}}\n",
        pq_json.join(",\n  ")
    );
    std::fs::write("bench_out/BENCH_pq.json", json).expect("write BENCH_pq.json");
    println!("wrote bench_out/BENCH_pq.json");

    println!(
        "\nreading: sq8 sits at ~4x; pq(m=dim/2, ksub=16) clears 16x on the hot\n\
         copy (nibble-packed codes) with the full-precision rows banished to the\n\
         cold rerank tier; recall climbs with rerank depth and reaches the exact\n\
         ranking as depth approaches N (the order-exactness property); OPQ's\n\
         rotation buys a few recall points at equal compression on correlated\n\
         embeddings. hnsw vs hnsw-plain in the first table isolates Malkov\n\
         Algorithm 4 heuristic neighbor selection."
    );

    // ---------------------------------------------------------------
    // Mmap cold-tier axis: the PQ rerank tier served from RAM vs from an
    // mmap'd on-disk vector file (ColdTier::Mmap) — resident vs mapped
    // bytes and QPS across rerank depths. Results land in
    // BENCH_mmap.json; the mapped tier must hold >= 0.5x the RAM-tier QPS
    // at the default rerank depth.
    // ---------------------------------------------------------------
    use opdr::index::ColdTier;
    let cold_dir = std::path::PathBuf::from("bench_out/cold_tier_bench");
    std::fs::create_dir_all("bench_out").expect("bench_out dir");
    section(&format!(
        "mmap cold-tier axis over {N} vectors at dim {dim}: pq rerank from ram vs mmap"
    ));
    let default_depth = IndexPolicy::default().rerank_depth;
    let mut mm_table = Table::new(&[
        "tier",
        "rerank depth",
        "recall@10",
        "qps",
        "resident KiB",
        "mapped KiB",
    ]);
    let mut mm_json: Vec<String> = Vec::new();
    let mut gate: (f64, f64) = (0.0, 0.0); // (ram qps, mmap qps) at the default depth
    for depth in [2 * K, default_depth] {
        for mmap in [false, true] {
            let policy = IndexPolicy {
                kind: IndexKind::Exact,
                exact_threshold: 0,
                pq: true,
                rerank_depth: depth,
                cold_tier: if mmap {
                    ColdTier::Mmap(cold_dir.clone())
                } else {
                    ColdTier::Ram
                },
                ..Default::default()
            };
            let tier = if mmap { "mmap" } else { "ram" };
            let idx = build_index(&base, dim, METRIC, &policy, 9).expect("build cold variant");
            let recall = recall_at_k(idx.as_ref(), &queries, dim, &truth);
            let r = bencher.run_items(&format!("pq {tier} d={depth}"), NQ as u64, || {
                for qi in 0..NQ {
                    let q = &queries[qi * dim..(qi + 1) * dim];
                    let out = idx.search(q, K).unwrap();
                    std::hint::black_box(out.len());
                }
            });
            let qps = r.throughput().unwrap_or(0.0);
            // Resident = hot copy + whatever part of the tier is not
            // mapped; mapped = bytes served zero-copy from the cold file.
            let mapped = idx.mapped_bytes();
            let resident = idx.memory_bytes() + idx.cold_bytes() - mapped;
            if depth == default_depth {
                if mmap {
                    gate.1 = qps;
                } else {
                    gate.0 = qps;
                }
            }
            mm_table.row(&[
                tier.to_string(),
                depth.to_string(),
                format!("{recall:.3}"),
                format!("{qps:.0}"),
                format!("{:.0}", resident as f64 / 1024.0),
                format!("{:.0}", mapped as f64 / 1024.0),
            ]);
            mm_json.push(format!(
                "{{\"tier\":\"{tier}\",\"rerank_depth\":{depth},\"recall_at_10\":{recall:.4},\
                 \"qps\":{qps:.1},\"resident_bytes\":{resident},\"mapped_bytes\":{mapped}}}"
            ));
        }
    }
    println!("{}", mm_table.render());
    // Acceptance bar: the mapped tier serves at >= 0.5x the RAM tier at the
    // default rerank depth (pages are cache-hot in steady state). On hosts
    // where mmap is unavailable the tier falls back to heap and trivially
    // passes.
    assert!(
        gate.1 >= 0.5 * gate.0,
        "mmap tier {:.0} qps < 0.5x ram tier {:.0} qps at depth {default_depth}",
        gate.1,
        gate.0
    );
    let json = format!(
        "{{\"bench\":\"index_mmap\",\"n\":{N},\"dim\":{dim},\"k\":{K},\"rows\":[\n  {}\n]}}\n",
        mm_json.join(",\n  ")
    );
    std::fs::write("bench_out/BENCH_mmap.json", json).expect("write BENCH_mmap.json");
    println!("wrote bench_out/BENCH_mmap.json");
    std::fs::remove_dir_all(&cold_dir).ok();

    println!(
        "\nreading: the rerank tier leaves RAM (resident drops by the cold\n\
         bytes, mapped rises by the same) while QPS stays within a small\n\
         factor of the RAM tier — the rows are page-cache-hot in steady\n\
         state, which is exactly the DiskANN/Lucene serving pattern that\n\
         lets collections larger than memory serve from one box."
    );

    // ---------------------------------------------------------------
    // Incremental-ingest axis: availability right after an ingest
    // (legacy invalidate → brute scan vs delta segment → index + exact
    // delta merge) and QPS while a background compaction rebuilds the
    // main index. Results land in BENCH_delta.json.
    // ---------------------------------------------------------------
    let delta_b = 256usize; // freshly ingested rows
    let main_rows = N - delta_b;
    section(&format!(
        "incremental-ingest axis: {main_rows} indexed + {delta_b} freshly ingested rows at dim {dim}"
    ));
    let mut delta_table = Table::new(&[
        "substrate",
        "mode",
        "post-ingest qps",
        "p50 / query µs",
        "qps during compaction",
    ]);
    let mut delta_json: Vec<String> = Vec::new();
    for (name, kind) in [("exact", IndexKind::Exact), ("hnsw", IndexKind::Hnsw)] {
        let policy = IndexPolicy { kind, exact_threshold: 0, ..Default::default() };
        let main: Arc<dyn AnnIndex> = Arc::from(
            build_index(&base[..main_rows * dim], dim, METRIC, &policy, 9).expect("build main"),
        );
        let wrapper = opdr::index::DeltaIndex::from_parts(
            Arc::clone(&main),
            base[main_rows * dim..].to_vec(),
        )
        .expect("wrap delta");

        // Legacy invalidate-on-ingest: every query brute-scans all N rows
        // until the next rebuild. Incremental: the index keeps serving with
        // an exact scan over only the delta tail merged in.
        let legacy = bencher.run_items(&format!("{name} legacy post-ingest"), NQ as u64, || {
            for qi in 0..NQ {
                let q = &queries[qi * dim..(qi + 1) * dim];
                let out = knn_indices(q, &base, dim, K, METRIC).unwrap();
                std::hint::black_box(out.len());
            }
        });
        let incremental = bencher.run_items(&format!("{name} delta post-ingest"), NQ as u64, || {
            for qi in 0..NQ {
                let q = &queries[qi * dim..(qi + 1) * dim];
                let out = wrapper.search(q, K).unwrap();
                std::hint::black_box(out.len());
            }
        });

        // QPS while a compaction (a pool rebuild over the merged rows) is
        // in flight — the wrapper keeps serving throughout; only the swap
        // at the end is atomic.
        let build_pool = ThreadPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        opdr::index::shard::build_on_pool(
            Arc::new(base.clone()),
            dim,
            METRIC,
            &policy,
            9,
            &build_pool,
            move |r| {
                let _ = tx.send(r.map(|_| ()));
            },
        );
        let sw = Stopwatch::start();
        let mut during = 0usize;
        loop {
            for qi in 0..NQ {
                let q = &queries[qi * dim..(qi + 1) * dim];
                let out = wrapper.search(q, K).unwrap();
                std::hint::black_box(out.len());
            }
            during += NQ;
            if rx.try_recv().is_ok() {
                break;
            }
        }
        let qps_during = during as f64 / sw.elapsed_secs().max(1e-9);

        for (mode, r) in [("legacy", &legacy), ("delta", &incremental)] {
            let qps = r.throughput().unwrap_or(0.0);
            let p50_us = r.percentile(0.5).as_nanos() as f64 / NQ as f64 / 1e3;
            let during_cell = if mode == "delta" { format!("{qps_during:.0}") } else { "-".into() };
            delta_table.row(&[
                name.to_string(),
                mode.to_string(),
                format!("{qps:.0}"),
                format!("{p50_us:.1}"),
                during_cell,
            ]);
            delta_json.push(format!(
                "{{\"substrate\":\"{name}\",\"mode\":\"{mode}\",\"ingested_rows\":{delta_b},\
                 \"post_ingest_qps\":{qps:.1},\"post_ingest_p50_us\":{p50_us:.2},\
                 \"qps_during_compaction\":{}}}",
                if mode == "delta" { format!("{qps_during:.1}") } else { "null".into() }
            ));
        }
    }
    println!("{}", delta_table.render());
    let json = format!(
        "{{\"bench\":\"index_delta\",\"n\":{N},\"dim\":{dim},\"k\":{K},\
         \"delta_rows\":{delta_b},\"rows\":[\n  {}\n]}}\n",
        delta_json.join(",\n  ")
    );
    std::fs::write("bench_out/BENCH_delta.json", json).expect("write BENCH_delta.json");
    println!("wrote bench_out/BENCH_delta.json");

    println!(
        "\nreading: the legacy rows are the ingest latency cliff this axis\n\
         measures — after any ingest the old path brute-scans all N rows until\n\
         a rebuild, while the delta path keeps the index and only adds an exact\n\
         scan over the freshly ingested tail; QPS during compaction shows the\n\
         wrapper serving at full speed while the merged index rebuilds in the\n\
         background (only the final swap is atomic)."
    );

    run_dist_axis();
}

// -------------------------------------------------------------------
// Distributed axis: the same exact scan served direct (single process)
// vs through the RPC gateway over 1 / 2 / 4 loopback shard workers
// ([`opdr::dist`]). Results land in BENCH_dist.json; the floor is
// CI-gated: 4-worker QPS must clear 1.5x the single-process QPS.
// -------------------------------------------------------------------
fn run_dist_axis() {
    use opdr::config::DistConfig;
    use opdr::dist::{Gateway, ThreadWorker, WorkerSpec};
    use opdr::index::shard::shard_ranges;
    use opdr::index::{ExactIndex, StorageSpec};
    use opdr::telemetry::Registry;

    const FLOOR_RATIO: f64 = 1.5;
    let n = 32_000usize;
    let dim = 64usize;
    let nq = 64usize;
    let set = synth::generate(DatasetKind::Flickr30k, n + nq, dim, 42);
    let base = &set.data()[..n * dim];
    let queries = &set.data()[n * dim..];
    section(&format!(
        "distributed axis over {n} vectors at dim {dim}: direct vs 1/2/4 shard workers"
    ));

    let whole: Arc<dyn AnnIndex> = Arc::new(
        ExactIndex::build(base, dim, METRIC, &StorageSpec::flat(), 9).expect("build reference"),
    );
    let reference: Vec<Vec<(usize, u32)>> = (0..8)
        .map(|qi| {
            whole
                .search(&queries[qi * dim..(qi + 1) * dim], K)
                .unwrap()
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect()
        })
        .collect();

    // Best-of-N rounds of the full query sweep: the gate compares
    // steady-state throughput, and best-of shields the CI step from
    // scheduler noise on shared runners.
    let bench_qps = |f: &mut dyn FnMut()| -> f64 {
        f(); // warmup sweep
        let mut best = 0.0f64;
        for _ in 0..5 {
            let sw = Stopwatch::start();
            f();
            best = best.max(nq as f64 / sw.elapsed_secs().max(1e-9));
        }
        best
    };

    let mut dist_table = Table::new(&["mode", "workers", "qps", "vs direct"]);
    let mut dist_json: Vec<String> = Vec::new();
    let direct_qps = bench_qps(&mut || {
        for qi in 0..nq {
            let out = whole.search(&queries[qi * dim..(qi + 1) * dim], K).unwrap();
            std::hint::black_box(out.len());
        }
    });
    dist_table.row(&["direct".into(), "0".into(), format!("{direct_qps:.0}"), "1.00x".into()]);
    dist_json.push(format!("{{\"mode\":\"direct\",\"workers\":0,\"qps\":{direct_qps:.1}}}"));

    let mut four_worker_qps = 0.0f64;
    for workers in [1usize, 2, 4] {
        let ranges = shard_ranges(n, workers, 1);
        let mut handles = Vec::new();
        let mut specs = Vec::new();
        for (i, r) in ranges.iter().enumerate() {
            let leaf: Arc<dyn AnnIndex> = Arc::new(
                ExactIndex::build(
                    &base[r.start * dim..r.end * dim],
                    dim,
                    METRIC,
                    &StorageSpec::flat(),
                    9,
                )
                .expect("build shard"),
            );
            let w = ThreadWorker::spawn(leaf, r.start).expect("spawn worker");
            specs.push(WorkerSpec::fixed(format!("w{i}"), w.addr()));
            handles.push(w);
        }
        let cfg = DistConfig {
            workers,
            listen: "127.0.0.1:0".to_string(),
            connect_timeout_ms: 2000,
            request_deadline_ms: 5000,
            ..Default::default()
        };
        let mut gw = Gateway::new(specs, cfg, Arc::new(Registry::new()));
        // Order-exactness spot check before timing anything: the gateway
        // must serve the reference ranking bitwise.
        for (qi, want) in reference.iter().enumerate() {
            let res = gw.search(&queries[qi * dim..(qi + 1) * dim], K).expect("gateway search");
            assert!(!res.partial, "healthy bench cluster answered partial");
            let got: Vec<(usize, u32)> =
                res.neighbors.iter().map(|nb| (nb.index, nb.distance.to_bits())).collect();
            assert_eq!(&got, want, "gateway diverged from the direct ranking (W={workers})");
        }
        let qps = bench_qps(&mut || {
            for qi in 0..nq {
                let res = gw.search(&queries[qi * dim..(qi + 1) * dim], K).unwrap();
                std::hint::black_box(res.neighbors.len());
            }
        });
        if workers == 4 {
            four_worker_qps = qps;
        }
        dist_table.row(&[
            "gateway".into(),
            workers.to_string(),
            format!("{qps:.0}"),
            format!("{:.2}x", qps / direct_qps.max(1e-9)),
        ]);
        dist_json.push(format!("{{\"mode\":\"gateway\",\"workers\":{workers},\"qps\":{qps:.1}}}"));
        for mut w in handles {
            w.kill();
        }
    }
    println!("{}", dist_table.render());

    let json = format!(
        "{{\"bench\":\"index_dist\",\"n\":{n},\"dim\":{dim},\"k\":{K},\
         \"floor_ratio\":{FLOOR_RATIO},\"direct_qps\":{direct_qps:.1},\
         \"four_worker_qps\":{four_worker_qps:.1},\"rows\":[\n  {}\n]}}\n",
        dist_json.join(",\n  ")
    );
    std::fs::create_dir_all("bench_out").expect("bench_out dir");
    std::fs::write("bench_out/BENCH_dist.json", json).expect("write BENCH_dist.json");
    println!("wrote bench_out/BENCH_dist.json");

    // Acceptance floor: scatter-gather over 4 workers must beat the
    // single-process scan by 1.5x — the scan parallelizes across worker
    // threads while the per-query RPC cost stays constant.
    assert!(
        four_worker_qps >= FLOOR_RATIO * direct_qps,
        "4-worker gateway {four_worker_qps:.0} qps < {FLOOR_RATIO}x single-process {direct_qps:.0} qps"
    );

    println!(
        "\nreading: the direct row is one thread scanning all rows per query;\n\
         each worker row scans 1/W of the rows concurrently behind one TCP\n\
         round-trip per shard, so QPS climbs toward the worker count until\n\
         the constant RPC cost dominates — the gated floor (4 workers >=\n\
         1.5x direct) is the point of the distribution layer."
    );

    run_obs_axis();
}

// -------------------------------------------------------------------
// Observability-overhead axis: an identical 2-worker gateway cluster
// benched with tracing OFF (`tracing = false` — v1-shaped frames, no
// trace tails, nothing recorded) vs tracing ON (default: trace ids on
// every query, stage histograms, flight recorder, plus one full
// MetricsPull federation scrape per query sweep). Results land in
// BENCH_obs.json; the floor is CI-gated: enabled must keep >= 0.9x the
// disabled QPS, i.e. cluster-wide observability may cost at most 10%.
// -------------------------------------------------------------------
fn run_obs_axis() {
    use opdr::config::DistConfig;
    use opdr::dist::{Gateway, ThreadWorker, WorkerSpec};
    use opdr::index::shard::shard_ranges;
    use opdr::index::{ExactIndex, StorageSpec};
    use opdr::telemetry::Registry;

    const FLOOR_RATIO: f64 = 0.9;
    let n = 32_000usize;
    let dim = 64usize;
    let nq = 64usize;
    let workers = 2usize;
    let set = synth::generate(DatasetKind::Flickr30k, n + nq, dim, 42);
    let base = &set.data()[..n * dim];
    let queries = &set.data()[n * dim..];
    section(&format!(
        "observability overhead over {n} vectors at dim {dim}: tracing+federation on vs off \
         ({workers} workers)"
    ));

    let bench_qps = |f: &mut dyn FnMut()| -> f64 {
        f(); // warmup sweep
        let mut best = 0.0f64;
        for _ in 0..5 {
            let sw = Stopwatch::start();
            f();
            best = best.max(nq as f64 / sw.elapsed_secs().max(1e-9));
        }
        best
    };

    // One cluster per mode so the enabled run's recorder/histogram state
    // never leaks into the baseline.
    let mut run_mode = |tracing: bool| -> f64 {
        let ranges = shard_ranges(n, workers, 1);
        let mut handles = Vec::new();
        let mut specs = Vec::new();
        for (i, r) in ranges.iter().enumerate() {
            let leaf: Arc<dyn AnnIndex> = Arc::new(
                ExactIndex::build(
                    &base[r.start * dim..r.end * dim],
                    dim,
                    METRIC,
                    &StorageSpec::flat(),
                    9,
                )
                .expect("build shard"),
            );
            let w = ThreadWorker::spawn(leaf, r.start).expect("spawn worker");
            specs.push(WorkerSpec::fixed(format!("w{i}"), w.addr()));
            handles.push(w);
        }
        let cfg = DistConfig {
            workers,
            connect_timeout_ms: 2000,
            request_deadline_ms: 5000,
            tracing,
            ..Default::default()
        };
        let mut gw = Gateway::new(specs, cfg, Arc::new(Registry::new()));
        let qps = bench_qps(&mut || {
            for qi in 0..nq {
                let res = gw.search(&queries[qi * dim..(qi + 1) * dim], K).unwrap();
                assert!(!res.partial, "healthy bench cluster answered partial");
                std::hint::black_box(res.neighbors.len());
            }
            if tracing {
                // The enabled mode pays for the whole observability
                // surface, federation scrape included.
                std::hint::black_box(gw.cluster_metrics().len());
            }
        });
        if tracing {
            assert!(
                gw.recorder().recorded_total() > 0,
                "enabled mode benched without recording anything"
            );
        }
        for mut w in handles {
            w.kill();
        }
        qps
    };

    let disabled_qps = run_mode(false);
    let enabled_qps = run_mode(true);
    let ratio = enabled_qps / disabled_qps.max(1e-9);
    let mut obs_table = Table::new(&["mode", "qps", "vs disabled"]);
    obs_table.row(&["tracing off".into(), format!("{disabled_qps:.0}"), "1.00x".into()]);
    obs_table.row(&[
        "tracing+federation".into(),
        format!("{enabled_qps:.0}"),
        format!("{ratio:.2}x"),
    ]);
    println!("{}", obs_table.render());

    let json = format!(
        "{{\"bench\":\"index_obs\",\"n\":{n},\"dim\":{dim},\"k\":{K},\"workers\":{workers},\
         \"floor_ratio\":{FLOOR_RATIO},\"disabled_qps\":{disabled_qps:.1},\
         \"enabled_qps\":{enabled_qps:.1},\"ratio\":{ratio:.4}}}\n"
    );
    std::fs::create_dir_all("bench_out").expect("bench_out dir");
    std::fs::write("bench_out/BENCH_obs.json", json).expect("write BENCH_obs.json");
    println!("wrote bench_out/BENCH_obs.json");

    // Acceptance floor: full observability — trace tails on every frame,
    // four stage histograms per shard per query, the recorder ring, and a
    // federation scrape per sweep — may cost at most 10% QPS.
    assert!(
        enabled_qps >= FLOOR_RATIO * disabled_qps,
        "observability-enabled {enabled_qps:.0} qps < {FLOOR_RATIO}x disabled {disabled_qps:.0} qps"
    );

    println!(
        "\nreading: both rows are the same 2-worker scatter-gather cluster; the\n\
         enabled row adds the 8-byte request tail, the 40-byte response tail,\n\
         per-stage histogram records on both sides, a flight-recorder push per\n\
         query and one MetricsPull federation scrape per sweep. The gated\n\
         floor (>= 0.9x) keeps always-on cluster observability honest."
    );
}
