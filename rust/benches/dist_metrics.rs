//! Distance-metric robustness (paper setup §Experimental Setup: "three
//! distance metrics — Euclidean, cosine, and Manhattan").
//!
//! Claim reproduced: the accuracy-vs-n/m log trend holds under all three
//! metrics on the same dataset, with metric-specific constants. Also benches
//! the per-metric pairwise-distance cost (the serving-relevant difference).
//!
//! Run: `cargo bench --bench dist_metrics`

use opdr::bench_support::{section, Bencher};
use opdr::data::{synth, DatasetKind};
use opdr::metrics::{pairwise_distances, Metric};
use opdr::opdr::{fit_log_model, sweep::SweepConfig};
use opdr::report::{write_csv, Table};
use opdr::util::Rng;

fn main() {
    let metrics = [Metric::SqEuclidean, Metric::Euclidean, Metric::Cosine, Metric::Manhattan];

    section("accuracy trend per metric (materials-observable, PCA)");
    let dim = 256;
    let set = synth::generate(DatasetKind::MaterialsObservable, 320, dim, 42);
    let mut table = Table::new(&["metric", "c0", "c1", "R²", "plateau"]);
    let mut rows = Vec::new();
    for metric in metrics {
        let cfg = SweepConfig {
            metric,
            sample_sizes: vec![30, 60, 80],
            dims_per_m: 8,
            repeats: 2,
            seed: 42,
            ..Default::default()
        };
        let curve = opdr::opdr::accuracy_curve(&set, &cfg).expect("sweep");
        let fit = fit_log_model(curve.points()).expect("fit");
        assert!(fit.c0 > 0.0, "{}: trend must hold", metric.name());
        table.row(&[
            metric.name().to_string(),
            format!("{:.4}", fit.c0),
            format!("{:.4}", fit.c1),
            format!("{:.3}", fit.r_squared),
            format!("{:.3}", curve.plateau_accuracy()),
        ]);
        rows.push(vec![
            metric.name().to_string(),
            format!("{}", fit.c0),
            format!("{}", fit.c1),
            format!("{}", fit.r_squared),
        ]);
    }
    println!("{}", table.render());
    write_csv("bench_out/dist_metrics.csv", &["metric", "c0", "c1", "r2"], &rows).expect("csv");

    section("pairwise-distance kernel cost per metric (Q=32, N=2048)");
    let bencher = Bencher::default();
    let mut rng = Rng::new(7);
    for d in [64usize, 256, 1024] {
        let queries = rng.normal_vec_f32(32 * d);
        let base = rng.normal_vec_f32(2048 * d);
        for metric in metrics {
            let (q, b) = (queries.clone(), base.clone());
            let r = bencher.run_items(&format!("pairwise/d{d}/{}", metric.name()), 32 * 2048, move || {
                let out = pairwise_distances(&q, &b, d, metric).unwrap();
                std::hint::black_box(out[0]);
            });
            println!("{}", r.summary());
        }
    }
    println!(
        "\nacceptance: every metric shows the log trend (paper: 'all results\n\
         suggest the proposed method is highly effective' across metrics)."
    );
}
