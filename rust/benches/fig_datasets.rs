//! Figures 1–6 (+ ESC-50): accuracy vs n/m per dataset.
//!
//! Paper setup: CLIP embeddings, L2 distance, PCA; materials subsets sweep
//! m ∈ {10..80}, web corpora m ∈ {10,50,100,150,300}. Prints the binned
//! series the paper plots, the Eq. (4) fit, and sweep wall-time; writes CSV
//! under bench_out/.
//!
//! Run: `cargo bench --bench fig_datasets`

use opdr::bench_support::{section, Bencher};
use opdr::data::{synth, DatasetKind};
use opdr::opdr::{fit_log_model, sweep::SweepConfig};
use opdr::report::{write_csv, Table};
use opdr::util::Stopwatch;

fn main() {
    let figures: [(DatasetKind, &str); 7] = [
        (DatasetKind::MaterialsObservable, "Figure 1: Observable Material"),
        (DatasetKind::MaterialsStable, "Figure 2: Stable Material"),
        (DatasetKind::MaterialsMetal, "Figure 3: Metal Material"),
        (DatasetKind::MaterialsMagnetic, "Figure 4: Magnetic Material"),
        (DatasetKind::Flickr30k, "Figure 5: Flickr30k"),
        (DatasetKind::OmniCorpus, "Figure 6: OmniCorpus"),
        (DatasetKind::Esc50, "ESC-50 (setup §Data Sets)"),
    ];
    let bencher =
        Bencher { warmup_iters: 0, iters: 2, max_time: std::time::Duration::from_secs(60) };
    let mut fit_rows = Vec::new();

    for (kind, title) in figures {
        section(title);
        let sizes = kind.paper_sample_sizes();
        let dim = kind.default_embed_dim().min(512); // CLIP-like geometry, capped for CPU
        let total = sizes.iter().max().unwrap() * 4;
        let set = synth::generate(kind, total, dim, 42);
        let cfg = SweepConfig {
            sample_sizes: sizes.clone(),
            dims_per_m: 10,
            repeats: 2,
            seed: 42,
            ..Default::default()
        };

        let sw = Stopwatch::start();
        let curve = opdr::opdr::accuracy_curve(&set, &cfg).expect("sweep");
        let sweep_time = sw.elapsed_secs();

        let mut table = Table::new(&["n/m", "accuracy"]);
        let mut csv_rows = Vec::new();
        for (r, a) in curve.binned(12) {
            table.row(&[format!("{r:.4}"), format!("{a:.4}")]);
            csv_rows.push(vec![format!("{r}"), format!("{a}")]);
        }
        println!("{}", table.render());
        let fit = fit_log_model(curve.points()).expect("fit");
        println!(
            "fit: A = {:.4}·ln(n/m) + {:.4}  R² = {:.3}  plateau = {:.3}  ({} pts, sweep {:.1}s)",
            fit.c0,
            fit.c1,
            fit.r_squared,
            curve.plateau_accuracy(),
            fit.n_points,
            sweep_time
        );
        write_csv(
            format!("bench_out/fig_{}.csv", kind.name()),
            &["ratio", "accuracy"],
            &csv_rows,
        )
        .expect("csv");
        fit_rows.push(vec![
            kind.name().to_string(),
            format!("{:.4}", fit.c0),
            format!("{:.4}", fit.c1),
            format!("{:.4}", fit.r_squared),
            format!("{:.4}", curve.plateau_accuracy()),
        ]);

        // Micro-bench: one full sweep iteration (the figure's compute cost).
        let set2 = set.clone();
        let cfg2 = cfg.clone();
        let r = bencher.run(&format!("sweep/{}", kind.name()), move || {
            let c = opdr::opdr::accuracy_curve(&set2, &cfg2).unwrap();
            std::hint::black_box(c.points().len());
        });
        println!("{}", r.summary());
    }

    section("Eq. (4) fits across datasets");
    let mut t = Table::new(&["dataset", "c0", "c1", "R²", "plateau"]);
    for row in &fit_rows {
        t.row(row);
    }
    println!("{}", t.render());
    write_csv(
        "bench_out/fig_datasets_fits.csv",
        &["dataset", "c0", "c1", "r2", "plateau"],
        &fit_rows,
    )
    .expect("csv");
    println!("acceptance: accuracy rises fast then converges on every dataset (paper Figs 1-6).");
}
