//! Serving benchmark: the paper's motivation quantified end-to-end.
//!
//! "Searching for KNN in multimodal data retrieval is computationally
//! expensive ... high dimensionality presents a challenge for time-sensitive
//! vision applications" — this bench measures the coordinator's throughput
//! and latency at full dimensionality vs the OPDR-planned dimension, plus a
//! dynamic-batcher max-wait ablation and (when artifacts exist) the PJRT
//! scoring path.
//!
//! Run: `cargo bench --bench serving`

use opdr::bench_support::section;
use opdr::config::ServeConfig;
use opdr::coordinator::Coordinator;
use opdr::data::{synth, DatasetKind};
use opdr::metrics::Metric;
use opdr::report::{write_csv, Table};
use opdr::util::Stopwatch;

const N: usize = 3000;
const DIM: usize = 1024;
const QUERIES: usize = 600;
const K: usize = 10;

fn storm(coord: &Coordinator, set: &opdr::data::EmbeddingSet) -> (f64, f64, f64) {
    // returns (qps, p50_ms, p99_ms) measured per-window
    let window = 64;
    let sw = Stopwatch::start();
    let mut lat = Vec::new();
    let mut qi = 0;
    while qi < QUERIES {
        let end = (qi + window).min(QUERIES);
        let mut rxs = Vec::new();
        for i in qi..end {
            if let Ok(rx) = coord.search_async("s", set.vector(i % N).to_vec(), K) {
                rxs.push(rx);
            }
        }
        let t0 = Stopwatch::start();
        for rx in rxs {
            let _ = rx.recv();
        }
        lat.push(t0.elapsed_ns() / window as f64 / 1e6);
        qi = end;
    }
    let secs = sw.elapsed_secs();
    lat.sort_by(f64::total_cmp);
    (
        QUERIES as f64 / secs,
        opdr::util::float::percentile_sorted(&lat, 0.5),
        opdr::util::float::percentile_sorted(&lat, 0.99),
    )
}

fn main() {
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    let mut rows = Vec::new();

    section("full-dim vs OPDR-reduced serving (CPU scoring path)");
    let mut table = Table::new(&["config", "serving dim", "qps", "p50 ms", "p99 ms"]);
    {
        let coord = Coordinator::start(ServeConfig::default()).unwrap();
        coord.create_collection("s", DIM, Metric::SqEuclidean).unwrap();
        coord.ingest("s", set.data().to_vec()).unwrap();
        let (qps, p50, p99) = storm(&coord, &set);
        table.row(&["full".into(), DIM.to_string(), format!("{qps:.0}"), format!("{p50:.2}"), format!("{p99:.2}")]);
        rows.push(vec!["full".to_string(), DIM.to_string(), format!("{qps}")]);

        for target in [0.8, 0.9, 0.95] {
            let dim = coord.build_reduced("s", target, K).unwrap();
            let (qps, p50, p99) = storm(&coord, &set);
            let label = format!("opdr A={target}");
            table.row(&[label.clone(), dim.to_string(), format!("{qps:.0}"), format!("{p50:.2}"), format!("{p99:.2}")]);
            rows.push(vec![label, dim.to_string(), format!("{qps}")]);
        }
        coord.shutdown();
    }
    println!("{}", table.render());
    write_csv("bench_out/serving.csv", &["config", "dim", "qps"], &rows).expect("csv");

    section("dynamic batcher: max_wait ablation (reduced collection, A=0.9)");
    let mut table = Table::new(&["max_wait ms", "max_batch", "qps", "batches", "avg batch"]);
    for (wait, batch) in [(0u64, 1usize), (1, 16), (2, 32), (8, 64)] {
        let cfg = ServeConfig {
            max_wait_ms: wait,
            max_batch: batch,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("s", DIM, Metric::SqEuclidean).unwrap();
        coord.ingest("s", set.data().to_vec()).unwrap();
        coord.build_reduced("s", 0.9, K).unwrap();
        let (qps, _, _) = storm(&coord, &set);
        let batches = coord.metrics().batches.get();
        let completed = coord.metrics().completed.get();
        table.row(&[
            wait.to_string(),
            batch.to_string(),
            format!("{qps:.0}"),
            batches.to_string(),
            format!("{:.1}", completed as f64 / batches.max(1) as f64),
        ]);
        coord.shutdown();
    }
    println!("{}", table.render());

    if std::path::Path::new("artifacts/manifest.toml").exists() {
        section("PJRT artifact scoring path (pairwise_topk, N≤1024 slice)");
        // The artifact caps N at 1024; serve a sliced collection both ways.
        let small = set.subset(&(0..1000).collect::<Vec<_>>()).unwrap();
        let mut table = Table::new(&["path", "qps", "p50 ms", "p99 ms"]);
        for use_runtime in [false, true] {
            let cfg = ServeConfig { use_runtime, max_batch: 32, ..Default::default() };
            let coord = Coordinator::start(cfg).unwrap();
            coord.create_collection("s", DIM, Metric::SqEuclidean).unwrap();
            coord.ingest("s", small.data().to_vec()).unwrap();
            let sw = Stopwatch::start();
            let mut lat = Vec::new();
            for i in 0..200 {
                let t0 = Stopwatch::start();
                let _ = coord.search("s", small.vector(i % 1000).to_vec(), K);
                lat.push(t0.elapsed_ns() / 1e6);
            }
            let secs = sw.elapsed_secs();
            lat.sort_by(f64::total_cmp);
            table.row(&[
                if use_runtime { "pjrt".into() } else { "cpu".to_string() },
                format!("{:.0}", 200.0 / secs),
                format!("{:.2}", opdr::util::float::percentile_sorted(&lat, 0.5)),
                format!("{:.2}", opdr::util::float::percentile_sorted(&lat, 0.99)),
            ]);
            coord.shutdown();
        }
        println!("{}", table.render());
        println!("note: the PJRT path runs the interpret-mode Pallas kernel — on CPU this is\na correctness/parity path; real-TPU perf is estimated in DESIGN.md §Perf.");
    } else {
        println!("(artifacts missing — skipping PJRT path; run `make artifacts`)");
    }
}
