//! Figures 10–12: PCA vs MDS fit lines on materials, Flickr, OmniCorpus.
//!
//! Paper claims: PCA is more sensitive to n/m, converges to higher accuracy
//! faster, and reaches 100% neighborhood preservation on the materials data;
//! MDS plateaus lower; both follow the log trend. We run classical MDS (the
//! Torgerson construction) and SMACOF (sklearn-like iterative stress
//! majorization, the paper's comparator behaviour).
//!
//! Run: `cargo bench --bench fig_reduction`

use opdr::bench_support::{section, Bencher};
use opdr::data::{synth, DatasetKind};
use opdr::opdr::{fit_log_model, sweep::SweepConfig};
use opdr::reduction::ReducerKind;
use opdr::report::{write_csv, Table};

fn main() {
    let figures: [(DatasetKind, &str); 3] = [
        (DatasetKind::MaterialsObservable, "Figure 10: PCA vs MDS on Material"),
        (DatasetKind::Flickr30k, "Figure 11: PCA vs MDS on Flickr"),
        (DatasetKind::OmniCorpus, "Figure 12: PCA vs MDS on OmniCorpus"),
    ];
    let reducers = [ReducerKind::Pca, ReducerKind::ClassicalMds, ReducerKind::Smacof];
    let bencher = Bencher::quick();

    for (kind, title) in figures {
        section(title);
        let dim = 256;
        let set = synth::generate(kind, 320, dim, 42);
        let mut table = Table::new(&["reducer", "c0", "c1", "R²", "plateau"]);
        let mut rows = Vec::new();
        let mut plateaus = std::collections::HashMap::new();
        for reducer in reducers {
            let cfg = SweepConfig {
                reducer,
                sample_sizes: vec![30, 60],
                dims_per_m: 8,
                repeats: 2,
                seed: 42,
                ..Default::default()
            };
            let curve = opdr::opdr::accuracy_curve(&set, &cfg).expect("sweep");
            let fit = fit_log_model(curve.points()).expect("fit");
            let plateau = curve.plateau_accuracy();
            plateaus.insert(reducer.name(), plateau);
            table.row(&[
                reducer.name().to_string(),
                format!("{:.4}", fit.c0),
                format!("{:.4}", fit.c1),
                format!("{:.3}", fit.r_squared),
                format!("{plateau:.3}"),
            ]);
            rows.push(vec![
                reducer.name().to_string(),
                format!("{}", fit.c0),
                format!("{}", fit.c1),
                format!("{}", fit.r_squared),
                format!("{plateau}"),
            ]);
        }
        println!("{}", table.render());
        println!(
            "note: classical (Torgerson) MDS on Euclidean distances is mathematically\n\
             identical to PCA (identical fits above confirm it); `smacof` is the\n\
             sklearn-like iterative comparator the paper actually plots as 'MDS'."
        );
        write_csv(
            format!("bench_out/fig_reduction_{}.csv", kind.name()),
            &["reducer", "c0", "c1", "r2", "plateau"],
            &rows,
        )
        .expect("csv");

        // The paper's ordering claim.
        let pca = plateaus["pca"];
        let mds = plateaus["mds"].max(plateaus["smacof"]);
        println!(
            "PCA plateau {pca:.3} vs best-MDS plateau {mds:.3} → {}",
            if pca >= mds - 1e-9 { "PCA wins (matches paper)" } else { "UNEXPECTED" }
        );
        if kind.is_materials() {
            println!(
                "materials peak accuracy (PCA): {pca:.3} (paper: reaches 1.00)"
            );
        }

        // Cost comparison at one representative cell (m=60, n=16).
        let sub = set.subset(&(0..60).collect::<Vec<_>>()).unwrap();
        for reducer in reducers {
            let data = sub.data().to_vec();
            let r = bencher.run(&format!("{}/m60/n16/{}", kind.name(), reducer.name()), move || {
                let out = reducer.build(0).fit_transform(&data, dim, 16).unwrap();
                std::hint::black_box(out.len());
            });
            println!("{}", r.summary());
        }
    }
    println!(
        "\nacceptance: PCA ≥ MDS at matched n/m everywhere; PCA hits ~1.0 on\n\
         materials; the log trend holds for both (paper Figs 10-12)."
    );
}
