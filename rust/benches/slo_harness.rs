//! Closed-loop SLO load harness: stepped target QPS against a live
//! coordinator with mixed search / ingest / compaction traffic, to find the
//! saturation knee — the highest offered rate the service still sustains at
//! ≥ 90% of target. Client-side latency is sampled per request, so the
//! percentiles include queueing, and the registry's stage histograms are
//! dumped afterwards to show where the time went.
//!
//! Emits `bench_out/BENCH_slo.json` with the per-step ladder and the knee,
//! and asserts a conservative CI floor on the knee QPS inside the binary.
//!
//! Run: `cargo bench --bench slo_harness` (append `-- --smoke` for the
//! short CI ladder).

use opdr::bench_support::section;
use opdr::config::ServeConfig;
use opdr::coordinator::Coordinator;
use opdr::data::{synth, DatasetKind, EmbeddingSet};
use opdr::metrics::Metric;
use opdr::report::Table;
use opdr::util::float::percentile_sorted;
use opdr::util::Stopwatch;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

const N: usize = 4000;
const DIM: usize = 128;
const K: usize = 10;
const CLIENTS: usize = 8;

struct StepOut {
    target_qps: f64,
    achieved_qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    completed: u64,
    rejected: u64,
}

/// One ladder step: `CLIENTS` closed-loop clients pace requests at
/// `target_qps / CLIENTS` each for `dur`, never queueing ahead of themselves
/// — when the service can't keep up, a client simply falls behind its
/// schedule and the achieved rate drops below target (the knee signal).
fn run_step(
    coord: &Coordinator,
    set: &EmbeddingSet,
    target_qps: f64,
    dur: Duration,
    writer_rows: &AtomicU64,
) -> StepOut {
    let interval = Duration::from_secs_f64(CLIENTS as f64 / target_qps);
    let stop = AtomicBool::new(false);
    let sw = Stopwatch::start();
    let (lat, rejected) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let stop = &stop;
            handles.push(s.spawn(move || {
                let mut lat: Vec<f64> = Vec::new();
                let mut rejected = 0u64;
                // Stagger clients so request arrivals interleave instead of
                // bursting in phase.
                std::thread::sleep(interval.mul_f64(c as f64 / CLIENTS as f64));
                let mut qi = c;
                let step_sw = Stopwatch::start();
                let mut deadline = Duration::ZERO;
                loop {
                    let elapsed = step_sw.elapsed();
                    if elapsed >= dur || stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if elapsed < deadline {
                        std::thread::sleep(deadline - elapsed);
                    } else {
                        // Behind schedule: issue immediately (closed loop —
                        // this is where saturation shows up as lost rate).
                        deadline = elapsed;
                    }
                    deadline += interval;
                    let t0 = Stopwatch::start();
                    match coord.search("slo", set.vector(qi % N).to_vec(), K) {
                        Ok(_) => lat.push(t0.elapsed_ns() / 1e6),
                        Err(_) => rejected += 1,
                    }
                    qi += CLIENTS;
                }
                (lat, rejected)
            }));
        }
        // Mixed traffic: a writer appends small batches throughout the step,
        // exercising the delta-append span and (past delta_max_vectors) the
        // background compaction + swap path.
        let writer = s.spawn(|| {
            let extra = synth::generate(DatasetKind::OmniCorpus, 32, DIM, 7);
            let mut rows = 0u64;
            while !stop.load(Ordering::Relaxed) {
                if coord.ingest("slo", extra.data().to_vec()).is_ok() {
                    rows += 32;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            rows
        });
        let mut lat = Vec::new();
        let mut rejected = 0u64;
        for h in handles {
            let (l, r) = h.join().expect("client thread");
            lat.extend(l);
            rejected += r;
        }
        stop.store(true, Ordering::Relaxed);
        writer_rows.fetch_add(writer.join().expect("writer thread"), Ordering::Relaxed);
        (lat, rejected)
    });
    let secs = sw.elapsed_secs();
    let mut lat = lat;
    lat.sort_by(f64::total_cmp);
    StepOut {
        target_qps,
        achieved_qps: lat.len() as f64 / secs,
        p50_ms: percentile_sorted(&lat, 0.5),
        p99_ms: percentile_sorted(&lat, 0.99),
        completed: lat.len() as u64,
        rejected,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (ladder, step_dur, floor_qps): (&[f64], Duration, f64) = if smoke {
        (&[200.0, 400.0, 800.0, 1600.0], Duration::from_millis(400), 50.0)
    } else {
        (&[250.0, 500.0, 1000.0, 2000.0, 4000.0, 8000.0], Duration::from_secs(2), 200.0)
    };

    let cfg = ServeConfig {
        workers: 4,
        max_batch: 32,
        max_wait_ms: 1,
        queue_capacity: 4096,
        ivf_threshold: 1024,
        delta_max_vectors: 512,
        ..Default::default()
    };
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("slo", DIM, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    coord.ingest("slo", set.data().to_vec()).unwrap();
    let sdim = coord.build_reduced("slo", 0.9, K).unwrap();
    // Serve from an IVF index so the writer's appends land in the delta
    // segment and push it over `delta_max_vectors` — real compaction/swap
    // traffic competing with the search load.
    coord.build_index("slo").unwrap();

    section(&format!(
        "SLO ladder: {} clients, mixed search+ingest, n={N} dim={DIM}→{sdim} ({})",
        CLIENTS,
        if smoke { "smoke" } else { "full" },
    ));
    let writer_rows = AtomicU64::new(0);
    let mut steps = Vec::new();
    let mut table =
        Table::new(&["target qps", "achieved", "p50 ms", "p99 ms", "completed", "rejected"]);
    for &target in ladder {
        let out = run_step(&coord, &set, target, step_dur, &writer_rows);
        table.row(&[
            format!("{target:.0}"),
            format!("{:.0}", out.achieved_qps),
            format!("{:.2}", out.p50_ms),
            format!("{:.2}", out.p99_ms),
            out.completed.to_string(),
            out.rejected.to_string(),
        ]);
        steps.push(out);
    }
    println!("{}", table.render());

    // The knee: the best achieved rate among steps that held ≥ 90% of their
    // target. If even the first step saturates, fall back to the best
    // achieved rate overall so the JSON still reports the capacity found.
    let knee_qps = steps
        .iter()
        .filter(|s| s.achieved_qps >= 0.9 * s.target_qps)
        .map(|s| s.achieved_qps)
        .fold(0.0f64, f64::max);
    let knee_qps = if knee_qps > 0.0 {
        knee_qps
    } else {
        steps.iter().map(|s| s.achieved_qps).fold(0.0f64, f64::max)
    };

    // Where the time went: the query-path stage histograms accumulated by
    // the very traffic above (scan/rerank/merge/delta_scan + queue wait),
    // and the write path's append/compaction/swap spans.
    let m = coord.metrics();
    let stage_ms = |h: &opdr::telemetry::LatencyHistogram| {
        format!(
            "p50={:.3}ms p99={:.3}ms n={}",
            h.quantile(0.5).as_secs_f64() * 1e3,
            h.quantile(0.99).as_secs_f64() * 1e3,
            h.count(),
        )
    };
    println!("stage queue_wait   {}", stage_ms(&m.queue_wait));
    println!("stage scan         {}", stage_ms(&m.trace.scan));
    println!("stage rerank       {}", stage_ms(&m.trace.rerank));
    println!("stage merge        {}", stage_ms(&m.trace.merge));
    println!("stage delta_scan   {}", stage_ms(&m.trace.delta_scan));
    println!("stage delta_append {}", stage_ms(&m.delta_append));
    println!("stage build        {}", stage_ms(&m.build_spans.build));
    println!("stage swap         {}", stage_ms(&m.build_spans.swap));

    let ingested = writer_rows.load(Ordering::Relaxed);
    let stats = coord.stats().unwrap();
    println!("{stats}");
    coord.shutdown();

    let step_json: Vec<String> = steps
        .iter()
        .map(|s| {
            format!(
                "    {{\"target_qps\": {:.1}, \"achieved_qps\": {:.1}, \"p50_ms\": {:.3}, \
                 \"p99_ms\": {:.3}, \"completed\": {}, \"rejected\": {}}}",
                s.target_qps,
                s.achieved_qps,
                s.p50_ms,
                s.p99_ms,
                s.completed,
                s.rejected,
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"slo_harness\",\n  \"mode\": \"{}\",\n  \"n\": {N},\n  \
         \"dim\": {DIM},\n  \"serving_dim\": {sdim},\n  \"clients\": {CLIENTS},\n  \
         \"ingested_rows\": {ingested},\n  \"steps\": [\n{}\n  ],\n  \
         \"knee_qps\": {knee_qps:.1},\n  \"floor_qps\": {floor_qps:.1}\n}}\n",
        if smoke { "smoke" } else { "full" },
        step_json.join(",\n"),
    );
    std::fs::create_dir_all("bench_out").expect("bench_out");
    std::fs::write("bench_out/BENCH_slo.json", &json).expect("write BENCH_slo.json");
    println!("wrote bench_out/BENCH_slo.json (knee ≈ {knee_qps:.0} qps)");

    // CI gate: the knee must clear a conservative floor — a regression that
    // tanks serving throughput (or breaks the mixed-traffic path outright)
    // fails the bench itself.
    assert!(
        knee_qps >= floor_qps,
        "SLO knee {knee_qps:.1} qps fell below the CI floor {floor_qps:.1} qps"
    );
}
