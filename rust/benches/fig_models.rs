//! Figures 7–9: fit lines per embedding model (BERT / ViT / CLIP) on the
//! materials, Flickr and OmniCorpus datasets.
//!
//! Paper claims: on materials data the three models' fit lines nearly
//! overlap; on Flickr/OmniCorpus the spread is visible but the log trend
//! holds for all. Uses the AOT-compiled towers via PJRT when artifacts are
//! present (the production path), else the hash-encoder fallback.
//!
//! Run: `cargo bench --bench fig_models`

use opdr::bench_support::section;
use opdr::data::records::generate_records;
use opdr::data::DatasetKind;
use opdr::embed::{embed_records, Encoder, HashEncoder, ModelKind, RuntimeEncoder};
use opdr::opdr::{fit_log_model, sweep::SweepConfig};
use opdr::report::{write_csv, Table};
use opdr::runtime::Engine;

fn main() {
    let figures: [(DatasetKind, &str); 3] = [
        (DatasetKind::MaterialsObservable, "Figure 7: models on Material"),
        (DatasetKind::Flickr30k, "Figure 8: models on Flickr"),
        (DatasetKind::OmniCorpus, "Figure 9: models on OmniCorpus"),
    ];
    let engine = Engine::new("artifacts").ok();
    let hash = HashEncoder::default();
    println!(
        "encoder backend: {}",
        if engine.is_some() { "pjrt-runtime (AOT towers)" } else { "hash-fallback" }
    );

    for (kind, title) in figures {
        section(title);
        let n = 240;
        let records = generate_records(kind, n, 42);
        let mut rows = Vec::new();
        let mut fits = Vec::new();
        let mut table = Table::new(&["model", "c0", "c1", "R²", "plateau"]);
        for model in ModelKind::FIGURE_MODELS {
            let set = match &engine {
                Some(eng) => {
                    let enc = RuntimeEncoder::new(eng);
                    embed_records(&enc, model, &records, kind.name()).expect("embed")
                }
                None => embed_records(&hash, model, &records, kind.name()).expect("embed"),
            };
            let cfg = SweepConfig {
                sample_sizes: vec![40, 80, 160],
                dims_per_m: 8,
                repeats: 2,
                seed: 42,
                ..Default::default()
            };
            let curve = opdr::opdr::accuracy_curve(&set, &cfg).expect("sweep");
            let fit = fit_log_model(curve.points()).expect("fit");
            table.row(&[
                model.name().to_string(),
                format!("{:.4}", fit.c0),
                format!("{:.4}", fit.c1),
                format!("{:.3}", fit.r_squared),
                format!("{:.3}", curve.plateau_accuracy()),
            ]);
            rows.push(vec![
                model.name().to_string(),
                format!("{}", fit.c0),
                format!("{}", fit.c1),
                format!("{}", fit.r_squared),
            ]);
            fits.push(fit);
            assert!(fit.c0 > 0.0, "{}: log trend must hold", model.name());
        }
        println!("{}", table.render());
        // Fit-line spread = max pairwise |ΔA| between model fit lines,
        // evaluated mid-sweep (the visual gap in the paper's plots).
        let at = |f: &opdr::opdr::fit::LogFit, r: f64| f.c0 * r.ln() + f.c1;
        let mut spread = 0.0f64;
        for r in [0.05, 0.1, 0.3] {
            for a in &fits {
                for b in &fits {
                    spread = spread.max((at(a, r) - at(b, r)).abs());
                }
            }
        }
        println!("fit-line spread across models (max |ΔA| mid-sweep): {spread:.4}");
        write_csv(
            format!("bench_out/fig_models_{}.csv", kind.name()),
            &["model", "c0", "c1", "r2"],
            &rows,
        )
        .expect("csv");
    }
    println!(
        "\nacceptance: all models follow the log trend; materials fit lines cluster\n\
         tighter than the web-corpora lines (paper Figs 7-9)."
    );
}
