//! Curse-of-dimensionality cost curve: exact KNN cost vs dimensionality.
//!
//! The paper's introduction motivates OPDR with the cost of KNN over
//! high-dimensional concatenated embeddings (BERT 768, ViT 768, CLIP 1024,
//! BERT⊕PANNs 2816). This bench measures brute-force query cost across that
//! dimension range — the denominator of every OPDR speedup claim — plus the
//! IVF-Flat index as the ANN baseline the paper cites (FAISS-style).
//!
//! Run: `cargo bench --bench knn_scaling`

use opdr::bench_support::{section, Bencher};
use opdr::data::{synth, DatasetKind};
use opdr::knn::IvfFlatIndex;
use opdr::metrics::Metric;
use opdr::report::{write_csv, Table};
use opdr::util::Rng;

fn main() {
    let n = 20_000;
    let dims = [32usize, 128, 512, 768, 1024, 2048, 2816];
    let bencher = Bencher::default();
    let mut rng = Rng::new(3);

    section(format!("brute-force 10-NN query cost vs dimension (N = {n})").as_str());
    let mut table = Table::new(&["dim", "mean/query", "queries/s"]);
    let mut rows = Vec::new();
    for &d in &dims {
        let base = rng.normal_vec_f32(n * d);
        let query = rng.normal_vec_f32(d);
        let r = bencher.run_items(&format!("brute/d{d}"), 1, {
            let base = base.clone();
            let query = query.clone();
            move || {
                let out = opdr::knn::knn_indices(&query, &base, d, 10, Metric::SqEuclidean).unwrap();
                std::hint::black_box(out[0].index);
            }
        });
        let qps = r.throughput().unwrap_or(0.0);
        table.row(&[
            d.to_string(),
            opdr::util::timer::fmt_duration(r.mean()),
            format!("{qps:.0}"),
        ]);
        rows.push(vec![d.to_string(), format!("{}", r.mean().as_nanos()), format!("{qps}")]);
    }
    println!("{}", table.render());
    write_csv("bench_out/knn_scaling.csv", &["dim", "mean_ns", "qps"], &rows).expect("csv");

    section("IVF-Flat (nlist=64) recall/latency trade-off at dim 256");
    let d = 256;
    let set = synth::generate(DatasetKind::Flickr30k, 10_000, d, 9);
    let index = IvfFlatIndex::build(set.data(), d, Metric::SqEuclidean, 64, 8, 1).unwrap();
    let queries = rng.normal_vec_f32(20 * d);
    let mut table = Table::new(&["nprobe", "recall@10", "mean/query"]);
    for nprobe in [1usize, 4, 8, 16, 64] {
        let recall = index.recall_at_k(&queries, 10, nprobe).unwrap();
        let q = queries[..d].to_vec();
        let idx = index.clone();
        let r = bencher.run(&format!("ivf/nprobe{nprobe}"), move || {
            let out = idx.search(&q, 10, nprobe).unwrap();
            std::hint::black_box(out.len());
        });
        table.row(&[
            nprobe.to_string(),
            format!("{recall:.3}"),
            opdr::util::timer::fmt_duration(r.mean()),
        ]);
    }
    println!("{}", table.render());
    println!(
        "\nreading: query cost grows ~linearly in dim — reducing 1024→~30 dims\n\
         (the planner's typical output at A=0.9) buys an order of magnitude,\n\
         which is what the serving bench observes end-to-end."
    );
}
