//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. PCA fit path: Gram trick (d > m) vs direct covariance — equality and
//!    cost (the reason the sweep engine is fast at the paper's m ≤ 300).
//! 2. Closed-form family: log vs linear vs sqrt in n/m — Eq. (4)'s log form
//!    must dominate on real sweep data.
//! 3. Robust vs OLS fitting under corrupted sweep cells.
//! 4. Measure k-sensitivity: A_k across k (the paper fixes k=5; show the
//!    trend is stable in k).
//!
//! Run: `cargo bench --bench ablations`

use opdr::bench_support::{section, Bencher};
use opdr::data::{synth, DatasetKind};
use opdr::metrics::Metric;
use opdr::opdr::fit::{fit_linear_model, fit_log_model, fit_log_model_huber, fit_sqrt_model};
use opdr::opdr::sweep::SweepConfig;
use opdr::reduction::{DimReducer, Pca};
use opdr::report::Table;
use opdr::util::Rng;

fn main() {
    let bencher = Bencher::default();

    section("ablation 1: PCA Gram trick vs covariance path");
    let mut table = Table::new(&["m", "d", "gram mean", "covariance mean", "max |Δ|"]);
    let mut rng = Rng::new(1);
    // d is capped at 512 here: the covariance path eigendecomposes d×d with
    // cyclic Jacobi (O(d³) per sweep), which is exactly why the Gram trick is
    // the default whenever d > m — at the paper's 2816 dims the covariance
    // path is minutes while Gram is milliseconds.
    for (m, d) in [(60usize, 256usize), (100, 512)] {
        let data = rng.normal_vec_f32(m * d);
        let target = 16;
        let gram_out = Pca::new().fit_transform(&data, d, target).unwrap();
        let cov_out = Pca { force_covariance: true }.fit_transform(&data, d, target).unwrap();
        // Sign-aligned max difference.
        let mut max_diff = 0.0f32;
        for c in 0..target {
            let dot: f32 = (0..m).map(|i| gram_out[i * target + c] * cov_out[i * target + c]).sum();
            let sign = dot.signum();
            for i in 0..m {
                max_diff = max_diff.max((gram_out[i * target + c] - sign * cov_out[i * target + c]).abs());
            }
        }
        let data_g = data.clone();
        let rg = bencher.run(&format!("pca-gram/m{m}/d{d}"), move || {
            std::hint::black_box(Pca::new().fit_transform(&data_g, d, target).unwrap().len());
        });
        let data_c = data.clone();
        let quick = Bencher::quick();
        let rc = quick.run(&format!("pca-cov/m{m}/d{d}"), move || {
            std::hint::black_box(
                Pca { force_covariance: true }.fit_transform(&data_c, d, target).unwrap().len(),
            );
        });
        table.row(&[
            m.to_string(),
            d.to_string(),
            opdr::util::timer::fmt_duration(rg.mean()),
            opdr::util::timer::fmt_duration(rc.mean()),
            format!("{max_diff:.2e}"),
        ]);
    }
    println!("{}", table.render());

    section("ablation 2: closed-form family on real sweep data");
    let set = synth::generate(DatasetKind::MaterialsObservable, 320, 256, 42);
    let cfg = SweepConfig { sample_sizes: vec![30, 60, 80], dims_per_m: 10, repeats: 2, ..Default::default() };
    let curve = opdr::opdr::accuracy_curve(&set, &cfg).unwrap();
    let log_fit = fit_log_model(curve.points()).unwrap();
    let lin_fit = fit_linear_model(curve.points()).unwrap();
    let sqrt_fit = fit_sqrt_model(curve.points()).unwrap();
    let mut table = Table::new(&["family", "R²"]);
    table.row(&["A = c0·ln(n/m) + c1 (paper Eq. 4)".into(), format!("{:.4}", log_fit.r_squared)]);
    table.row(&["A = c0·(n/m) + c1".into(), format!("{:.4}", lin_fit.r_squared)]);
    table.row(&["A = c0·sqrt(n/m) + c1".into(), format!("{:.4}", sqrt_fit.r_squared)]);
    println!("{}", table.render());
    println!(
        "log form {} (paper's hypothesis {})",
        if log_fit.r_squared >= lin_fit.r_squared.max(sqrt_fit.r_squared) { "wins" } else { "does NOT win" },
        if log_fit.r_squared >= lin_fit.r_squared.max(sqrt_fit.r_squared) { "confirmed" } else { "falsified on this draw" },
    );

    section("ablation 3: OLS vs Huber under corrupted sweep cells");
    let mut pts = curve.points().to_vec();
    let n_corrupt = pts.len() / 10;
    let len = pts.len();
    for i in 0..n_corrupt {
        pts[i * 7 % len].1 = 0.0; // hard outliers
    }
    let ols = fit_log_model(&pts).unwrap();
    let huber = fit_log_model_huber(&pts, 0.05, 30).unwrap();
    println!(
        "clean c0 = {:.4}; corrupted OLS c0 = {:.4} (Δ {:.4}); Huber c0 = {:.4} (Δ {:.4})",
        log_fit.c0,
        ols.c0,
        (ols.c0 - log_fit.c0).abs(),
        huber.c0,
        (huber.c0 - log_fit.c0).abs()
    );

    section("ablation 4: k-sensitivity of the accuracy trend");
    let mut table = Table::new(&["k", "c0", "c1", "R²"]);
    for k in [1usize, 3, 5, 10] {
        let cfg = SweepConfig {
            k,
            sample_sizes: vec![40, 80],
            dims_per_m: 8,
            repeats: 2,
            ..Default::default()
        };
        let curve = opdr::opdr::accuracy_curve(&set, &cfg).unwrap();
        let fit = fit_log_model(curve.points()).unwrap();
        table.row(&[
            k.to_string(),
            format!("{:.4}", fit.c0),
            format!("{:.4}", fit.c1),
            format!("{:.3}", fit.r_squared),
        ]);
    }
    println!("{}", table.render());
    println!("acceptance: positive slope at every k — the measure is stable in k.");

    // Keep Metric import used for future extension and to document intent.
    let _ = Metric::SqEuclidean;
}
