//! Cross-module property tests on the mini-proptest harness.

use opdr::metrics::Metric;
use opdr::opdr::measure::{op_measure, NeighborSets};
use opdr::opdr::{accuracy, fit_log_model, Planner};
use opdr::reduction::ReducerKind;
use opdr::testing::{forall, gen, PropConfig};

const METRICS: [Metric; 4] =
    [Metric::SqEuclidean, Metric::Euclidean, Metric::Cosine, Metric::Manhattan];

#[test]
fn prop_measure_is_additive_and_bounded() {
    forall(
        PropConfig { cases: 40, seed: 101 },
        |rng| {
            let (x, dx, m) = gen::embedding_block(rng, 8, 24, 4, 16);
            let dy = 1 + rng.below(dx);
            let y = rng.normal_vec_f32(m * dy);
            let k = 1 + rng.below((m - 1).min(6));
            let metric = METRICS[rng.below(4)];
            // Random disjoint partition of all indices into 3 parts.
            let mut parts: Vec<Vec<usize>> = vec![vec![], vec![], vec![]];
            for i in 0..m {
                parts[rng.below(3)].push(i);
            }
            (x, dx, y, dy, m, k, metric, parts)
        },
        |(x, dx, y, dy, m, k, metric, parts)| {
            let sets = NeighborSets::compute(x, *dx, y, *dy, *k, *metric)
                .map_err(|e| e.to_string())?;
            for i in 0..*m {
                let whole: Vec<usize> = (0..*m).collect();
                let mu_whole = op_measure(&sets, i, &whole);
                if !(0.0..=1.0).contains(&mu_whole) {
                    return Err(format!("μ out of range: {mu_whole}"));
                }
                let sum: f64 = parts.iter().map(|p| op_measure(&sets, i, p)).sum();
                if (mu_whole - sum).abs() > 1e-9 {
                    return Err(format!("additivity violated: {mu_whole} vs {sum}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_accuracy_bounds_and_identity() {
    forall(
        PropConfig { cases: 30, seed: 202 },
        |rng| {
            let (x, dx, m) = gen::embedding_block(rng, 8, 30, 3, 12);
            let k = 1 + rng.below((m - 1).min(5));
            let metric = METRICS[rng.below(4)];
            (x, dx, k, metric)
        },
        |(x, dx, k, metric)| {
            // Identity map: accuracy exactly 1.
            let a = accuracy(x, *dx, x, *dx, *k, *metric).map_err(|e| e.to_string())?;
            if a != 1.0 {
                return Err(format!("identity accuracy {a} != 1"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reducers_produce_valid_output() {
    forall(
        PropConfig { cases: 25, seed: 303 },
        |rng| {
            let (x, dx, m) = gen::embedding_block(rng, 6, 20, 4, 20);
            let target = 1 + rng.below(dx.min(m));
            let kind = [
                ReducerKind::Pca,
                ReducerKind::ClassicalMds,
                ReducerKind::Smacof,
                ReducerKind::RandomProjection,
                ReducerKind::Identity,
            ][rng.below(5)];
            (x, dx, m, target, kind)
        },
        |(x, dx, m, target, kind)| {
            let out = kind
                .build(7)
                .fit_transform(x, *dx, *target)
                .map_err(|e| format!("{}: {e}", kind.name()))?;
            if out.len() != m * target {
                return Err(format!("{}: wrong output size", kind.name()));
            }
            if out.iter().any(|v| !v.is_finite()) {
                return Err(format!("{}: non-finite output", kind.name()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_planner_inversion_consistent() {
    forall(
        PropConfig { cases: 50, seed: 404 },
        |rng| {
            // Random plausible fits: c0 in (0.02, 0.5], c1 in [0.3, 1.1].
            let c0 = 0.02 + rng.uniform() * 0.48;
            let c1 = 0.3 + rng.uniform() * 0.8;
            let m = 10 + rng.below(500);
            let target = 0.2 + rng.uniform() * 0.75;
            (c0, c1, m, target)
        },
        |&(c0, c1, m, target)| {
            let fit = opdr::opdr::fit::LogFit { c0, c1, r_squared: 1.0, n_points: 10 };
            let planner = Planner::from_fit(fit);
            let n = planner.dim_for_accuracy(target, m);
            if n < 1 || n > m {
                return Err(format!("planned dim {n} outside [1, {m}]"));
            }
            // Forward prediction at the planned dim must reach the target
            // (unless clamped at m, where the best achievable is predict(1)).
            let pred = planner.predicted_accuracy(n, m);
            if n < m && pred + 1e-6 < target.min(1.0) {
                return Err(format!("pred {pred} < target {target} at n={n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fit_recovers_generating_coefficients() {
    forall(
        PropConfig { cases: 30, seed: 505 },
        |rng| {
            let c0 = 0.05 + rng.uniform() * 0.3;
            let c1 = 0.5 + rng.uniform() * 0.4;
            let pts: Vec<(f64, f64)> = (0..30)
                .map(|i| {
                    let r = 0.05 + 0.95 * (i as f64 / 29.0);
                    let a = (c0 * r.ln() + c1).clamp(0.0, 1.0);
                    (r, a)
                })
                .collect();
            (c0, c1, pts)
        },
        |(c0, c1, pts)| {
            // Only use the unclamped midsection for exact recovery.
            let interior: Vec<(f64, f64)> =
                pts.iter().copied().filter(|&(_, a)| a > 1e-9 && a < 1.0 - 1e-9).collect();
            if interior.len() < 5 {
                return Ok(()); // degenerate draw; skip
            }
            let fit = fit_log_model(&interior).map_err(|e| e.to_string())?;
            if (fit.c0 - c0).abs() > 1e-6 || (fit.c1 - c1).abs() > 1e-6 {
                return Err(format!(
                    "recovered ({}, {}) from ({c0}, {c1})",
                    fit.c0, fit.c1
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_matches_sort_under_duplicates() {
    forall(
        PropConfig { cases: 60, seed: 606 },
        |rng| {
            // Heavy duplicates to stress tie-breaking.
            let n = 1 + rng.below(100);
            let vals: Vec<f32> = (0..n).map(|_| (rng.below(5) as f32) * 0.25).collect();
            let k = 1 + rng.below(12);
            (vals, k)
        },
        |(vals, k)| {
            let fast = opdr::knn::top_k_smallest(vals, *k);
            let mut idx: Vec<usize> = (0..vals.len()).collect();
            idx.sort_by(|&a, &b| {
                vals[a].total_cmp(&vals[b]).then(a.cmp(&b))
            });
            let want: Vec<usize> = idx.into_iter().take(*k.min(&vals.len())).collect();
            let got: Vec<usize> = fast.iter().map(|x| x.0).collect();
            if got != want {
                return Err(format!("topk {got:?} != sort {want:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_full_oracle_under_nans_ties_and_large_k() {
    // The tie-break contract every index substrate relies on: results equal
    // the full sort of the finite entries by (value, index), NaNs skipped,
    // k ≥ len returns everything finite, and returned distances are the
    // source values bit-for-bit.
    forall(
        PropConfig { cases: 120, seed: 808 },
        |rng| {
            let n = rng.below(60); // includes the empty slice
            let vals: Vec<f32> = (0..n)
                .map(|_| match rng.below(8) {
                    0 => f32::NAN,
                    1 => 0.25, // heavy ties
                    2 => -0.25,
                    3 => f32::INFINITY,
                    4 => 0.0,
                    _ => rng.normal() as f32,
                })
                .collect();
            let k = rng.below(80); // 0, < len, and >= len all exercised
            (vals, k)
        },
        |(vals, k)| {
            let fast = opdr::knn::top_k_smallest(vals, *k);
            let mut idx: Vec<usize> = (0..vals.len()).filter(|&i| !vals[i].is_nan()).collect();
            idx.sort_by(|&a, &b| vals[a].total_cmp(&vals[b]).then(a.cmp(&b)));
            let want: Vec<usize> = idx.into_iter().take(*k).collect();
            let got: Vec<usize> = fast.iter().map(|x| x.0).collect();
            if got != want {
                return Err(format!("topk {got:?} != oracle {want:?}"));
            }
            for &(i, d) in &fast {
                if d.to_bits() != vals[i].to_bits() {
                    return Err(format!("value at {i} not preserved: {d} vs {}", vals[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_index_substrates_agree_with_exact_at_full_beam() {
    // With exhaustive parameters (full probe / ef ≥ n) every substrate must
    // return exactly the brute-force ranking — same indices, same order.
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, IndexKind};
    forall(
        PropConfig { cases: 12, seed: 909 },
        |rng| {
            let (data, dim, m) = gen::embedding_block(rng, 10, 40, 2, 8);
            let q = rng.normal_vec_f32(dim);
            let k = 1 + rng.below(m.min(8));
            (data, dim, q, k)
        },
        |(data, dim, q, k)| {
            let exact =
                opdr::knn::knn_indices(q, data, *dim, *k, Metric::SqEuclidean)
                    .map_err(|e| e.to_string())?;
            let want: Vec<usize> = exact.iter().map(|n| n.index).collect();
            let n = data.len() / dim;
            for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
                // Exhaustive parameters: full IVF probe; HNSW degree cap 2m ≥ n
                // (no pruning can disconnect) with beam ef ≥ n (visits the
                // whole component), so every substrate must be exact.
                let policy = IndexPolicy {
                    kind,
                    exact_threshold: 0,
                    ivf_nlist: n,
                    ivf_nprobe: n,
                    hnsw_m: n.max(2),
                    hnsw_ef_search: 4 * n,
                    ..Default::default()
                };
                let idx = build_index(data, *dim, Metric::SqEuclidean, &policy, 5)
                    .map_err(|e| e.to_string())?;
                let got: Vec<usize> =
                    idx.search(q, *k).map_err(|e| e.to_string())?.iter().map(|n| n.index).collect();
                if got != want {
                    return Err(format!("{}: {got:?} != exact {want:?}", kind.name()));
                }
            }
            Ok(())
        },
    );
}

/// Tentpole exactness proof, part 1 — the fan-out/merge is *order-exact*
/// for every substrate ± SQ8: searching a `ShardedIndex` (serially or fanned
/// out on the pool) returns byte-identical neighbors to independently
/// searching the same per-shard segments and merging their remapped hits
/// under the global (distance, index) order — including heavy ties,
/// NaN-distance vectors and k ≥ N.
#[test]
fn prop_sharded_merge_is_order_exact_for_every_substrate() {
    use opdr::config::IndexPolicy;
    use opdr::coordinator::ThreadPool;
    use opdr::index::shard::{shard_ranges, shard_seed, ShardedIndex};
    use opdr::index::{build_index, AnnIndex as _, IndexKind};
    let pool = ThreadPool::new(3);
    forall(
        PropConfig { cases: 20, seed: 4242 },
        |rng| {
            let m = 6 + rng.below(36);
            let dim = 2 + rng.below(6);
            let mut data = gen::vec_f32(rng, m * dim);
            // Duplicate some rows so (distance, index) tie-breaking is load-
            // bearing across shard boundaries.
            for i in 1..m {
                if rng.below(4) == 0 {
                    let src = rng.below(i);
                    data.copy_within(src * dim..(src + 1) * dim, i * dim);
                }
            }
            // Sometimes poison a row with NaN (skipped by the top-k
            // contract). SQ8 training rejects non-finite input, and ANN
            // structure builds over NaN rows are undefined, so NaN cases
            // exercise the exact substrate.
            let nan_row = if rng.below(3) == 0 { Some(rng.below(m)) } else { None };
            if let Some(rix) = nan_row {
                data[rix * dim] = f32::NAN;
            }
            let s = 2 + rng.below(4);
            let k = rng.below(m + 4); // 0, < m and ≥ m all exercised
            let metric = METRICS[rng.below(4)];
            let q = gen::vec_f32(rng, dim);
            (data, dim, m, s, k, metric, q, nan_row.is_some())
        },
        |(data, dim, m, s, k, metric, q, has_nan)| {
            let substrates: &[(IndexKind, bool)] = if *has_nan {
                &[(IndexKind::Exact, false)]
            } else {
                &[
                    (IndexKind::Exact, false),
                    (IndexKind::Exact, true),
                    (IndexKind::Ivf, false),
                    (IndexKind::Ivf, true),
                    (IndexKind::Hnsw, false),
                    (IndexKind::Hnsw, true),
                ]
            };
            for &(kind, sq8) in substrates {
                let policy = IndexPolicy {
                    kind,
                    sq8,
                    exact_threshold: 0,
                    shards: *s,
                    shard_min_vectors: 1,
                    ivf_nlist: 3,
                    ivf_nprobe: 2,
                    ..Default::default()
                };
                let tag = format!("{}{} S={s}", kind.name(), if sq8 { "+sq8" } else { "" });
                let sharded = ShardedIndex::build(data, *dim, *metric, &policy, 77)
                    .map_err(|e| format!("{tag}: {e}"))?;
                // Reference: same leaf builds (same partition, same per-shard
                // seeds), searched independently, remapped and merged by a
                // plain total-order sort.
                let leaf = IndexPolicy { shards: 1, ..policy.clone() };
                let mut reference: Vec<(usize, u32, f32)> = Vec::new();
                for (si, r) in shard_ranges(*m, *s, 1).iter().enumerate() {
                    let seg = build_index(
                        &data[r.start * dim..r.end * dim],
                        *dim,
                        *metric,
                        &leaf,
                        shard_seed(77, si),
                    )
                    .map_err(|e| format!("{tag} shard {si}: {e}"))?;
                    for nb in seg.search(q, *k).map_err(|e| format!("{tag}: {e}"))? {
                        reference.push((nb.index + r.start, nb.distance.to_bits(), nb.distance));
                    }
                }
                reference.sort_by(|a, b| {
                    a.2.total_cmp(&b.2).then(a.0.cmp(&b.0))
                });
                reference.truncate(*k);
                let want: Vec<(usize, u32)> =
                    reference.into_iter().map(|(i, bits, _)| (i, bits)).collect();

                let serial: Vec<(usize, u32)> = sharded
                    .search(q, *k)
                    .map_err(|e| format!("{tag}: {e}"))?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                if serial != want {
                    return Err(format!("{tag}: serial merge {serial:?} != reference {want:?}"));
                }
                let fanned: Vec<(usize, u32)> = sharded
                    .search_on(&pool, q, *k)
                    .map_err(|e| format!("{tag}: {e}"))?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                if fanned != serial {
                    return Err(format!("{tag}: pool fan-out {fanned:?} != serial {serial:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Tentpole exactness proof, part 2 — at exhaustive parameters (exact scan;
/// IVF at full probe; HNSW with degree cap ≥ n and beam ≥ 4n) a sharded
/// index over *any* substrate returns the same neighbor IDs and bit-
/// identical distances as the unsharded index over the whole collection.
#[test]
fn prop_sharded_equals_unsharded_at_exhaustive_params() {
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, IndexKind};
    forall(
        PropConfig { cases: 10, seed: 5151 },
        |rng| {
            let (data, dim, m) = gen::embedding_block(rng, 8, 36, 2, 8);
            let s = 2 + rng.below(4);
            let k = 1 + rng.below(m + 2);
            let metric = METRICS[rng.below(4)];
            let q = gen::vec_f32(rng, dim);
            (data, dim, m, s, k, metric, q)
        },
        |(data, dim, m, s, k, metric, q)| {
            let n = *m;
            for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
                let sharded_policy = IndexPolicy {
                    kind,
                    exact_threshold: 0,
                    shards: *s,
                    shard_min_vectors: 1,
                    ivf_nlist: n,
                    ivf_nprobe: n,
                    hnsw_m: n.max(2),
                    hnsw_ef_search: 4 * n,
                    ..Default::default()
                };
                let unsharded_policy = IndexPolicy { shards: 1, ..sharded_policy.clone() };
                let single = build_index(data, *dim, *metric, &unsharded_policy, 5)
                    .map_err(|e| e.to_string())?;
                let sharded = build_index(data, *dim, *metric, &sharded_policy, 5)
                    .map_err(|e| e.to_string())?;
                if sharded.as_sharded().is_none() {
                    return Err(format!("{}: expected a sharded index", kind.name()));
                }
                let a: Vec<(usize, u32)> = single
                    .search(q, *k)
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                let b: Vec<(usize, u32)> = sharded
                    .search(q, *k)
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                if a != b {
                    return Err(format!(
                        "{} S={s}: sharded {b:?} != unsharded {a:?}",
                        kind.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Tentpole exactness proof (PR 3) — the PQ two-stage search is *order-
/// exact at full depth* for every substrate, sharded and unsharded: with
/// exhaustive substrate parameters (exact scan; IVF at full probe; HNSW at
/// degree cap ≥ n, beam ≥ 4n) and `rerank_depth ≥ n`, a PQ-compressed
/// index (± OPQ rotation) returns **bit-identical** neighbors to the flat
/// [`opdr::index::ExactIndex`] over the same rows — including duplicate
/// rows (tie-breaking across shard boundaries), NaN queries (both sides
/// return empty) and k ≥ N. Compression costs zero correctness once the
/// full-precision rerank has the whole candidate set.
#[test]
fn prop_pq_rerank_is_order_exact_at_full_depth() {
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, ExactIndex, IndexKind, StorageSpec};
    forall(
        PropConfig { cases: 14, seed: 6161 },
        |rng| {
            let m = 6 + rng.below(30);
            let dim = 2 + rng.below(6);
            let mut data = gen::vec_f32(rng, m * dim);
            // Duplicate rows: (distance, index) tie-breaking must survive
            // both the ADC candidate stage and the rerank merge.
            for i in 1..m {
                if rng.below(4) == 0 {
                    let src = rng.below(i);
                    data.copy_within(src * dim..(src + 1) * dim, i * dim);
                }
            }
            let s = 1 + rng.below(4); // 1 = unsharded
            let k = rng.below(m + 4); // 0, < m and ≥ m all exercised
            let metric = METRICS[rng.below(4)];
            // Sometimes a NaN query: every variant must return empty.
            let q = if rng.below(5) == 0 {
                vec![f32::NAN; dim]
            } else {
                gen::vec_f32(rng, dim)
            };
            let opq = rng.below(2) == 0;
            let ksub = 2 + rng.below(15); // spans packed (≤16) space
            (data, dim, m, s, k, metric, q, opq, ksub)
        },
        |(data, dim, m, s, k, metric, q, opq, ksub)| {
            let n = *m;
            // Ground truth: flat exact scan (the contract's reference).
            let flat = ExactIndex::build(data, *dim, *metric, &StorageSpec::flat(), 5)
                .map_err(|e| e.to_string())?;
            let want: Vec<(usize, u32)> = flat
                .search(q, *k)
                .map_err(|e| e.to_string())?
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
                let policy = IndexPolicy {
                    kind,
                    exact_threshold: 0,
                    pq: true,
                    pq_opq: *opq,
                    pq_ksub: *ksub,
                    pq_train_iters: 4,
                    pq_opq_iters: 2,
                    rerank_depth: n + 3,
                    shards: *s,
                    shard_min_vectors: 1,
                    ivf_nlist: n,
                    ivf_nprobe: n,
                    hnsw_m: n.max(2),
                    hnsw_ef_search: 4 * n,
                    ..Default::default()
                };
                let idx = build_index(data, *dim, *metric, &policy, 5)
                    .map_err(|e| format!("{} S={s}: {e}", kind.name()))?;
                if (*s > 1) != idx.as_sharded().is_some() {
                    return Err(format!("{} S={s}: unexpected sharding", kind.name()));
                }
                if !idx.quantized() || idx.storage_name() != "pq" {
                    return Err(format!("{} S={s}: not pq-quantized", kind.name()));
                }
                let got: Vec<(usize, u32)> = idx
                    .search(q, *k)
                    .map_err(|e| format!("{} S={s}: {e}", kind.name()))?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                if got != want {
                    return Err(format!(
                        "{} S={s} opq={opq} ksub={ksub}: pq {got:?} != exact {want:?}",
                        kind.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Satellite (PR 3): with `sq8_global_codebook` one codebook is trained over
/// the whole collection, so at exhaustive parameters the *quantized* sharded
/// index is bit-identical to the *quantized* unsharded index for every
/// substrate (the segment-local default only guarantees exactness of the
/// merge, not cross-shard codebook equality — the PR 2 ROADMAP note this
/// closes).
#[test]
fn prop_sq8_global_codebook_sharded_equals_unsharded() {
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, IndexKind};
    forall(
        PropConfig { cases: 10, seed: 7272 },
        |rng| {
            let (data, dim, m) = gen::embedding_block(rng, 8, 36, 2, 8);
            let s = 2 + rng.below(4);
            let k = 1 + rng.below(m + 2);
            let metric = METRICS[rng.below(4)];
            let q = gen::vec_f32(rng, dim);
            (data, dim, m, s, k, metric, q)
        },
        |(data, dim, m, s, k, metric, q)| {
            let n = *m;
            for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
                let sharded_policy = IndexPolicy {
                    kind,
                    exact_threshold: 0,
                    sq8: true,
                    sq8_global_codebook: true,
                    shards: *s,
                    shard_min_vectors: 1,
                    ivf_nlist: n,
                    ivf_nprobe: n,
                    hnsw_m: n.max(2),
                    hnsw_ef_search: 4 * n,
                    ..Default::default()
                };
                let unsharded_policy = IndexPolicy { shards: 1, ..sharded_policy.clone() };
                let single = build_index(data, *dim, *metric, &unsharded_policy, 5)
                    .map_err(|e| e.to_string())?;
                let sharded = build_index(data, *dim, *metric, &sharded_policy, 5)
                    .map_err(|e| e.to_string())?;
                if sharded.as_sharded().is_none() {
                    return Err(format!("{}: expected a sharded index", kind.name()));
                }
                if !sharded.quantized() {
                    return Err(format!("{}: expected sq8 storage", kind.name()));
                }
                let a: Vec<(usize, u32)> = single
                    .search(q, *k)
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                let b: Vec<(usize, u32)> = sharded
                    .search(q, *k)
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                if a != b {
                    return Err(format!(
                        "{} S={s}: global-codebook sharded {b:?} != unsharded {a:?}",
                        kind.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Acceptance criterion (PR 3): at the default `m = dim/2`, `ksub = 16`
/// configuration the PQ hot serving copy is at least 8× smaller than flat
/// f32 on a realistically sized block, and the two-stage search still finds
/// the encoded vectors themselves. (The CI bench-smoke step runs this in
/// release.)
#[test]
fn pq_compression_ratio_at_least_8x() {
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, IndexKind};
    use opdr::util::Rng;
    let n = 3000;
    let dim = 32;
    let data = Rng::new(77).normal_vec_f32(n * dim);
    let flat_bytes = n * dim * std::mem::size_of::<f32>();
    let policy = IndexPolicy {
        kind: IndexKind::Exact,
        exact_threshold: 0,
        pq: true,
        rerank_depth: 128,
        ..Default::default()
    };
    let idx = build_index(&data, dim, opdr::metrics::Metric::SqEuclidean, &policy, 7).unwrap();
    let ratio = flat_bytes as f64 / idx.memory_bytes() as f64;
    assert!(ratio >= 8.0, "pq compression {ratio:.2}x < 8x ({} bytes)", idx.memory_bytes());
    // The cold rerank tier is accounted separately and equals the raw rows.
    assert_eq!(idx.cold_bytes(), flat_bytes);
    // Self-hits survive the two-stage search at a practical rerank depth.
    for qi in [0usize, 999, 2999] {
        let q = &data[qi * dim..(qi + 1) * dim];
        let hits = idx.search(q, 1).unwrap();
        assert_eq!(hits[0].index, qi, "self-hit lost under pq");
    }
}

/// Tentpole exactness proof (PR 4) — incremental ingest is *order-exact*:
/// a [`opdr::index::DeltaIndex`] wrapping a main index built over the first
/// `n0` rows plus a flat delta holding the remaining rows (appended in one
/// or several ingest batches) searches **bitwise identically** to a freshly
/// built flat [`opdr::index::ExactIndex`] over the concatenated rows, for
/// every substrate at exhaustive parameters (exact scan; IVF at full probe;
/// HNSW at degree cap ≥ n, beam ≥ 4n) × storage (flat; PQ at full rerank
/// depth) × sharded/unsharded main — including duplicate rows straddling
/// the main/delta boundary (global (distance, index) tie-breaking), NaN
/// delta rows and NaN queries (skipped on both sides), and k ≥ N. SQ8
/// storage defines its distances relative to the main's codebooks, so
/// there the wrapper is checked against the order-exact reference merge of
/// the independently searched parts (the same contract the shard merge
/// honors) — as is every other combination, on top of the bitwise check.
#[test]
fn prop_delta_search_is_order_exact_for_every_substrate_and_storage() {
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, DeltaIndex, ExactIndex, IndexKind, StorageSpec};
    use std::sync::Arc;
    forall(
        PropConfig { cases: 10, seed: 9393 },
        |rng| {
            let m = 6 + rng.below(30);
            let dim = 2 + rng.below(6);
            let mut data = gen::vec_f32(rng, m * dim);
            // Duplicate rows so (distance, index) tie-breaking is load-
            // bearing across the main/delta boundary.
            for i in 1..m {
                if rng.below(4) == 0 {
                    let src = rng.below(i);
                    data.copy_within(src * dim..(src + 1) * dim, i * dim);
                }
            }
            let n0 = 2 + rng.below(m - 3); // main prefix; delta keeps >= 2 rows
            // Sometimes poison a *delta* row with NaN (the delta is never
            // quantized and never fed to an ANN build, so every substrate
            // and storage must tolerate it; main rows stay finite).
            if rng.below(3) == 0 {
                let rix = n0 + rng.below(m - n0);
                data[rix * dim] = f32::NAN;
            }
            let batches = 1 + rng.below(3); // ingest the delta in 1..=3 batches
            let s = 1 + rng.below(3); // 1 = unsharded main
            let k = rng.below(m + 4); // 0, < m and >= m all exercised
            let metric = METRICS[rng.below(4)];
            let q = if rng.below(6) == 0 { vec![f32::NAN; dim] } else { gen::vec_f32(rng, dim) };
            (data, dim, m, n0, batches, s, k, metric, q)
        },
        |(data, dim, m, n0, batches, s, k, metric, q)| {
            let (n, n0) = (*m, *n0);
            // Ground truth: flat exact scan over the concatenated rows.
            let flat = ExactIndex::build(data, *dim, *metric, &StorageSpec::flat(), 5)
                .map_err(|e| e.to_string())?;
            let want: Vec<(usize, u32)> = flat
                .search(q, *k)
                .map_err(|e| e.to_string())?
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
                for storage in ["f32", "sq8", "pq"] {
                    let policy = IndexPolicy {
                        kind,
                        exact_threshold: 0,
                        sq8: storage == "sq8",
                        pq: storage == "pq",
                        pq_train_iters: 4,
                        pq_opq_iters: 2,
                        rerank_depth: n0 + 3,
                        shards: *s,
                        shard_min_vectors: 1,
                        ivf_nlist: n0,
                        ivf_nprobe: n0,
                        hnsw_m: n0.max(2),
                        hnsw_ef_search: 4 * n0,
                        ..Default::default()
                    };
                    let tag = format!("{}+{storage} S={s} n0={n0}/{n}", kind.name());
                    let main: Arc<dyn opdr::index::AnnIndex> = Arc::from(
                        build_index(&data[..n0 * dim], *dim, *metric, &policy, 5)
                            .map_err(|e| format!("{tag}: {e}"))?,
                    );
                    // Assemble the wrapper the way ingest does: an initial
                    // wrap plus zero or more extensions, in `batches` steps.
                    let delta_rows = &data[n0 * dim..];
                    let delta_n = n - n0;
                    let per = delta_n.div_ceil(*batches);
                    let mut wrapper = DeltaIndex::from_parts(
                        Arc::clone(&main),
                        delta_rows[..per.min(delta_n) * dim].to_vec(),
                    )
                    .map_err(|e| format!("{tag}: {e}"))?;
                    let mut at = per.min(delta_n);
                    while at < delta_n {
                        let end = (at + per).min(delta_n);
                        wrapper = wrapper
                            .extended(&delta_rows[at * dim..end * dim])
                            .map_err(|e| format!("{tag}: {e}"))?;
                        at = end;
                    }
                    if wrapper.len() != n || wrapper.delta_len() != delta_n {
                        return Err(format!("{tag}: wrapper assembled {} rows", wrapper.len()));
                    }
                    let got: Vec<(usize, u32)> = wrapper
                        .search(q, *k)
                        .map_err(|e| format!("{tag}: {e}"))?
                        .iter()
                        .map(|nb| (nb.index, nb.distance.to_bits()))
                        .collect();
                    // Reference merge: the main searched independently plus
                    // a flat exact scan of the delta rows, merged under the
                    // global (distance, index) total order.
                    let delta_exact =
                        ExactIndex::build(delta_rows, *dim, *metric, &StorageSpec::flat(), 5)
                            .map_err(|e| format!("{tag}: {e}"))?;
                    let mut reference: Vec<(usize, u32, f32)> = Vec::new();
                    for nb in main.search(q, *k).map_err(|e| format!("{tag}: {e}"))? {
                        reference.push((nb.index, nb.distance.to_bits(), nb.distance));
                    }
                    for nb in delta_exact.search(q, *k).map_err(|e| format!("{tag}: {e}"))? {
                        reference.push((nb.index + n0, nb.distance.to_bits(), nb.distance));
                    }
                    reference.sort_by(|a, b| a.2.total_cmp(&b.2).then(a.0.cmp(&b.0)));
                    reference.truncate(*k);
                    let reference: Vec<(usize, u32)> =
                        reference.into_iter().map(|(i, bits, _)| (i, bits)).collect();
                    if got != reference {
                        return Err(format!(
                            "{tag}: wrapper {got:?} != reference merge {reference:?}"
                        ));
                    }
                    // Exactness-preserving storages: bitwise equal to the
                    // flat exact index over the concatenated rows.
                    if storage != "sq8" && got != want {
                        return Err(format!("{tag}: wrapper {got:?} != flat exact {want:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Tentpole exactness proof (PR 5) — the mmap cold tier costs zero
/// correctness: for every substrate at exhaustive parameters (exact scan;
/// IVF at full probe; HNSW at degree cap ≥ n, beam ≥ 4n) × storage with a
/// full-precision tier (flat; PQ at full rerank depth) × sharded/unsharded,
/// an index built with `ColdTier::Mmap` (rows spilled to and served from
/// on-disk vector files) returns **bit-identical** neighbors to the same
/// index built with the RAM tier — and a version-5 save/load round trip
/// (both the mmap'd and the forced-heap load) stays bitwise too, including
/// duplicate rows, NaN queries and k ≥ N.
#[test]
fn prop_mmap_rerank_matches_ram_tier() {
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, ColdTier, IndexKind};
    let dir = std::env::temp_dir().join(format!("opdr_props_cold_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        PropConfig { cases: 6, seed: 2525 },
        |rng| {
            let m = 6 + rng.below(24);
            let dim = 2 + rng.below(6);
            let mut data = gen::vec_f32(rng, m * dim);
            // Duplicate rows so tie-breaking is load-bearing through the
            // tier as well.
            for i in 1..m {
                if rng.below(4) == 0 {
                    let src = rng.below(i);
                    data.copy_within(src * dim..(src + 1) * dim, i * dim);
                }
            }
            let s = 1 + rng.below(3); // 1 = unsharded
            let k = rng.below(m + 4);
            let metric = METRICS[rng.below(4)];
            let q = if rng.below(6) == 0 { vec![f32::NAN; dim] } else { gen::vec_f32(rng, dim) };
            (data, dim, m, s, k, metric, q)
        },
        |(data, dim, m, s, k, metric, q)| {
            let n = *m;
            for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
                for storage in ["f32", "pq"] {
                    let ram_policy = IndexPolicy {
                        kind,
                        exact_threshold: 0,
                        pq: storage == "pq",
                        pq_train_iters: 4,
                        rerank_depth: n + 3,
                        shards: *s,
                        shard_min_vectors: 1,
                        ivf_nlist: n,
                        ivf_nprobe: n,
                        hnsw_m: n.max(2),
                        hnsw_ef_search: 4 * n,
                        ..Default::default()
                    };
                    let mmap_policy = IndexPolicy {
                        cold_tier: ColdTier::Mmap(dir.clone()),
                        ..ram_policy.clone()
                    };
                    let tag = format!("{}+{storage} S={s}", kind.name());
                    let ram = build_index(data, *dim, *metric, &ram_policy, 5)
                        .map_err(|e| format!("{tag} ram: {e}"))?;
                    let cold = build_index(data, *dim, *metric, &mmap_policy, 5)
                        .map_err(|e| format!("{tag} mmap: {e}"))?;
                    if !cold.matches_data(data) {
                        return Err(format!("{tag}: tiered rows diverged from the input"));
                    }
                    let want: Vec<(usize, u32)> = ram
                        .search(q, *k)
                        .map_err(|e| format!("{tag}: {e}"))?
                        .iter()
                        .map(|nb| (nb.index, nb.distance.to_bits()))
                        .collect();
                    let got: Vec<(usize, u32)> = cold
                        .search(q, *k)
                        .map_err(|e| format!("{tag}: {e}"))?
                        .iter()
                        .map(|nb| (nb.index, nb.distance.to_bits()))
                        .collect();
                    if got != want {
                        return Err(format!("{tag}: mmap tier {got:?} != ram tier {want:?}"));
                    }
                    // Version-5 round trip: the mmap'd load and the forced
                    // heap load are both bitwise equal to the RAM tier.
                    let path = dir.join(format!("prop-{}-{storage}-{s}.opdx", kind.name()));
                    opdr::data::store::save_index_cold(cold.as_ref(), &path)
                        .map_err(|e| format!("{tag} save: {e}"))?;
                    for (mode, loaded) in [
                        ("mmap", opdr::data::store::load_index(&path)),
                        ("heap", opdr::data::store::load_index_heap(&path)),
                    ] {
                        let loaded = loaded.map_err(|e| format!("{tag} load {mode}: {e}"))?;
                        let back: Vec<(usize, u32)> = loaded
                            .search(q, *k)
                            .map_err(|e| format!("{tag} {mode}: {e}"))?
                            .iter()
                            .map(|nb| (nb.index, nb.distance.to_bits()))
                            .collect();
                        if back != want {
                            return Err(format!(
                                "{tag}: v5 {mode} load {back:?} != ram tier {want:?}"
                            ));
                        }
                    }
                    std::fs::remove_file(&path).ok();
                }
            }
            Ok(())
        },
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// CI gate (release only): serving PQ rerank from the mmap'd cold tier
/// must hold at least half the RAM-tier QPS at the default rerank depth —
/// the mapped rows are page-cache-hot in steady state, so the tier's cost
/// is bounded. Skipped under debug builds (unoptimized timing is noise).
#[test]
fn mmap_cold_tier_serves_at_half_ram_qps() {
    use opdr::config::IndexPolicy;
    use opdr::index::{build_index, AnnIndex as _, ColdTier, IndexKind};
    use opdr::util::Rng;
    if cfg!(debug_assertions) {
        eprintln!("mmap_cold_tier_serves_at_half_ram_qps: skipped under debug_assertions");
        return;
    }
    let dir = std::env::temp_dir().join(format!("opdr_props_coldqps_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let n = 3000;
    let dim = 32;
    let data = Rng::new(99).normal_vec_f32(n * dim);
    let queries = Rng::new(101).normal_vec_f32(64 * dim);
    let base = IndexPolicy {
        kind: IndexKind::Exact,
        exact_threshold: 0,
        pq: true,
        ..Default::default() // default rerank_depth
    };
    let ram = build_index(&data, dim, opdr::metrics::Metric::SqEuclidean, &base, 7).unwrap();
    let cold_policy = IndexPolicy { cold_tier: ColdTier::Mmap(dir.clone()), ..base };
    let cold =
        build_index(&data, dim, opdr::metrics::Metric::SqEuclidean, &cold_policy, 7).unwrap();
    let bench = |idx: &dyn opdr::index::AnnIndex| -> f64 {
        // Warm up (pages the tier in), then take the best of several timed
        // rounds — the gate compares steady-state serving cost, and
        // best-of-N shields the required CI step from scheduler noise on
        // shared runners.
        for qi in 0..64 {
            idx.search(&queries[qi * dim..(qi + 1) * dim], 10).unwrap();
        }
        let mut best = 0.0f64;
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            let mut count = 0u64;
            for _ in 0..4 {
                for qi in 0..64 {
                    let out = idx.search(&queries[qi * dim..(qi + 1) * dim], 10).unwrap();
                    std::hint::black_box(out.len());
                    count += 1;
                }
            }
            let qps = count as f64 / t0.elapsed().as_secs_f64().max(1e-9);
            best = best.max(qps);
        }
        best
    };
    let ram_qps = bench(ram.as_ref());
    let cold_qps = bench(cold.as_ref());
    assert!(
        cold_qps >= 0.5 * ram_qps,
        "mmap tier {cold_qps:.0} qps < 0.5x ram tier {ram_qps:.0} qps"
    );
    drop(cold);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_store_roundtrip() {
    forall(
        PropConfig { cases: 20, seed: 707 },
        |rng| {
            let (data, dim, _) = gen::embedding_block(rng, 1, 20, 1, 16);
            (data, dim)
        },
        |(data, dim)| {
            let set = opdr::data::EmbeddingSet::new("prop", *dim, data.clone())
                .map_err(|e| e.to_string())?;
            let mut buf = Vec::new();
            opdr::data::store::write_embeddings(&set, &mut buf).map_err(|e| e.to_string())?;
            let back =
                opdr::data::store::read_embeddings(&mut buf.as_slice()).map_err(|e| e.to_string())?;
            if back != set {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

/// PR 7 satellite — every RPC message round-trips through the frame codec
/// bit-exactly (NaN payloads included), and the decoder survives adversarial
/// mutation of any frame: a huge declared length fails before allocation, a
/// lying under-cap length ends in the typed truncation error instead of an
/// OOM, any flipped payload byte fails the CRC, bad magic / unknown kind /
/// trailing bytes are typed errors, and truncation at every boundary never
/// panics.
#[test]
fn prop_rpc_frame_roundtrip() {
    use opdr::rpc::{
        decode_frame, encode_frame, Message, WireTrace, HEADER_BYTES, MAX_PAYLOAD_BYTES,
    };
    forall(
        PropConfig { cases: 60, seed: 7171 },
        |rng| {
            let rid = rng.next_u64();
            let msg = match rng.below(9) {
                0 => Message::Hello { version: rng.next_u64() as u32 },
                1 => Message::HelloAck {
                    version: rng.next_u64() as u32,
                    start: rng.next_u64(),
                    len: rng.next_u64(),
                    dim: rng.next_u64() as u32,
                },
                2 => {
                    let n = rng.below(64);
                    let mut query = gen::vec_f32(rng, n);
                    if n > 0 && rng.below(3) == 0 {
                        // A NaN with an arbitrary mantissa must survive the
                        // wire bit-exactly (the merge compares raw bits).
                        let at = rng.below(n);
                        query[at] =
                            f32::from_bits(0x7FC0_0000 | (rng.next_u64() as u32 & 0x003F_FFFF));
                    }
                    // Half the cases carry the v2 trace tail.
                    let trace_id = if rng.below(2) == 0 { None } else { Some(rng.next_u64()) };
                    Message::Search { k: rng.below(1000) as u32, query, trace_id }
                }
                3 => Message::SearchOk {
                    neighbors: (0..rng.below(48))
                        .map(|_| (rng.next_u64(), f32::from_bits(rng.next_u64() as u32)))
                        .collect(),
                    trace: if rng.below(2) == 0 {
                        None
                    } else {
                        Some(WireTrace {
                            trace_id: rng.next_u64(),
                            queue_ns: rng.next_u64(),
                            scan_ns: rng.next_u64(),
                            rerank_ns: rng.next_u64(),
                            merge_ns: rng.next_u64(),
                        })
                    },
                },
                4 => Message::Error {
                    message: (0..rng.below(40))
                        .map(|_| char::from(b'a' + rng.below(26) as u8))
                        .collect(),
                },
                5 => Message::Ping,
                6 => Message::MetricsPull,
                7 => Message::MetricsText {
                    text: (0..rng.below(60))
                        .map(|_| char::from(b' ' + rng.below(90) as u8))
                        .collect(),
                },
                _ => Message::Pong,
            };
            (rid, msg, rng.below(512), rng.below(512))
        },
        |(rid, msg, cut, flip)| {
            let bytes = encode_frame(*rid, msg).map_err(|e| e.to_string())?;
            let (got_rid, decoded) = decode_frame(&bytes).map_err(|e| e.to_string())?;
            if got_rid != *rid {
                return Err(format!("rid {got_rid} != {rid}"));
            }
            let re = encode_frame(got_rid, &decoded).map_err(|e| e.to_string())?;
            if re != bytes {
                return Err(format!("{}: re-encode differs from the original", msg.kind_name()));
            }
            // Truncation at both edges and a random boundary: typed errors.
            for cut in [0, bytes.len() - 1, cut % bytes.len()] {
                if decode_frame(&bytes[..cut]).is_ok() {
                    return Err(format!("truncated frame (cut at {cut}) decoded"));
                }
            }
            // Over-cap length field: refused before any allocation.
            let mut huge = bytes.clone();
            huge[13..17].copy_from_slice(&u32::MAX.to_le_bytes());
            let err = decode_frame(&huge).err().ok_or("over-cap length decoded")?;
            if !err.to_string().contains("byte cap") {
                return Err(format!("over-cap length: wrong error: {err}"));
            }
            // Under-cap but lying length field: bounded read hits EOF.
            let mut lying = bytes.clone();
            lying[13..17].copy_from_slice(&((MAX_PAYLOAD_BYTES - 1) as u32).to_le_bytes());
            if decode_frame(&lying).is_ok() {
                return Err("lying length field decoded".into());
            }
            // Any flipped payload byte fails the CRC (checked before the
            // payload is parsed, so corruption is never misread as data).
            let payload_len = bytes.len() - HEADER_BYTES;
            if payload_len > 0 {
                let mut corrupt = bytes.clone();
                corrupt[HEADER_BYTES + flip % payload_len] ^= 0x40;
                let err = decode_frame(&corrupt).err().ok_or("corrupt payload decoded")?;
                if !err.to_string().contains("crc") {
                    return Err(format!("corruption: wrong error: {err}"));
                }
            }
            // Bad magic, unknown kind and trailing bytes are each typed.
            let mut bad_magic = bytes.clone();
            bad_magic[0] ^= 0x01;
            let err = decode_frame(&bad_magic).err().ok_or("bad magic decoded")?;
            if !err.to_string().contains("magic") {
                return Err("bad magic: wrong error".into());
            }
            let mut bad_kind = bytes.clone();
            bad_kind[4] = 0;
            let err = decode_frame(&bad_kind).err().ok_or("bad kind decoded")?;
            if !err.to_string().contains("kind") {
                return Err("bad kind: wrong error".into());
            }
            let mut trailing = bytes.clone();
            trailing.push(0);
            if decode_frame(&trailing).is_ok() {
                return Err("trailing byte after the frame decoded".into());
            }
            Ok(())
        },
    );
}

/// PR 7 tentpole proof — a [`opdr::dist::Gateway`] fanning out over real
/// loopback-TCP shard workers returns **bit-identical** neighbors to the
/// in-process sharded search (itself proven equal to the unsharded index by
/// `prop_sharded_equals_unsharded_at_exhaustive_params`) for every substrate
/// × storage at exhaustive parameters — including duplicate rows
/// (cross-shard ties), NaN queries (both sides empty) and k ≥ N. The
/// workers serve the *same* leaf segments via
/// [`opdr::index::ShardedIndex::segment`], so even segment-local compressed
/// codebooks travel bitwise: distances cross the wire as raw f32 bits.
#[test]
fn prop_distributed_search_is_order_exact() {
    use opdr::config::{DistConfig, IndexPolicy};
    use opdr::dist::{Gateway, ThreadWorker, WorkerSpec};
    use opdr::index::{build_index, AnnIndex as _, IndexKind};
    use opdr::telemetry::Registry;
    use std::sync::Arc;
    forall(
        PropConfig { cases: 6, seed: 8181 },
        |rng| {
            let (mut data, dim, m) = gen::embedding_block(rng, 8, 36, 2, 8);
            // Duplicate rows: (distance, index) tie-breaking must survive
            // the remap through worker-global ids.
            for i in 1..m {
                if rng.below(4) == 0 {
                    let src = rng.below(i);
                    data.copy_within(src * dim..(src + 1) * dim, i * dim);
                }
            }
            let s = 2 + rng.below(3);
            let k = rng.below(m + 3); // 0, < m and ≥ m all exercised
            let metric = METRICS[rng.below(4)];
            let q = if rng.below(6) == 0 {
                vec![f32::NAN; dim]
            } else {
                gen::vec_f32(rng, dim)
            };
            let storage = rng.below(3); // flat | sq8 | pq at full depth
            (data, dim, m, s, k, metric, q, storage)
        },
        |(data, dim, m, s, k, metric, q, storage)| {
            let n = *m;
            for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
                let policy = IndexPolicy {
                    kind,
                    exact_threshold: 0,
                    shards: *s,
                    shard_min_vectors: 1,
                    ivf_nlist: n,
                    ivf_nprobe: n,
                    hnsw_m: n.max(2),
                    hnsw_ef_search: 4 * n,
                    sq8: *storage == 1,
                    pq: *storage == 2,
                    pq_m: 1, // one subquantizer: valid at any (odd) dim
                    rerank_depth: n + 8,
                    ..Default::default()
                };
                let built = build_index(data, *dim, *metric, &policy, 5)
                    .map_err(|e| e.to_string())?;
                let sharded = built
                    .as_sharded()
                    .ok_or_else(|| format!("{}: expected a sharded index", kind.name()))?;
                // Serve the exact same leaf segments over loopback TCP.
                let mut workers = Vec::new();
                let mut specs = Vec::new();
                for sh in 0..sharded.num_shards() {
                    let w = ThreadWorker::spawn(
                        sharded.segment(sh),
                        sharded.shard_range(sh).start,
                    )
                    .map_err(|e| e.to_string())?;
                    specs.push(WorkerSpec::fixed(format!("w{sh}"), w.addr()));
                    workers.push(w);
                }
                let cfg = DistConfig {
                    workers: workers.len(),
                    listen: "127.0.0.1:0".to_string(),
                    connect_timeout_ms: 2000,
                    request_deadline_ms: 4000,
                    ..Default::default()
                };
                let mut gw = Gateway::new(specs, cfg, Arc::new(Registry::new()));
                let res = gw.search(q, *k).map_err(|e| e.to_string())?;
                if res.partial {
                    return Err(format!("{}: healthy cluster answered partial", kind.name()));
                }
                let got: Vec<(usize, u32)> = res
                    .neighbors
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                let want: Vec<(usize, u32)> = built
                    .search(q, *k)
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                if got != want {
                    return Err(format!(
                        "{} S={s} storage={storage}: gateway {got:?} != in-process {want:?}",
                        kind.name()
                    ));
                }
                for mut w in workers {
                    w.kill();
                }
            }
            Ok(())
        },
    );
}
