//! Cluster-wide observability integration tests.
//!
//! Three guarantees are machine-checked here:
//!
//! 1. **Federation is lossless.** A worker registry scraped over
//!    `MetricsPull` and reloaded from its snapshot renders **bit-for-bit**
//!    identically to the worker's own exposition, and the federated
//!    cluster exposition's unlabeled aggregates equal the per-worker sums
//!    exactly (`_count`) / to float tolerance (`_sum`).
//! 2. **Trace ids survive the fault matrix.** Under every fault kind ×
//!    protocol stage the traced query lands in the flight recorder with
//!    its trace id, per-shard stage timings for every surviving shard,
//!    and — for every partial answer — a FAIL disposition naming the
//!    faulted shard.
//! 3. **The recall probe rides the distributed path deterministically.**
//!    Sampled gateway answers shadow-executed against the unreduced corpus
//!    publish recall@k and μ gauges; two identical runs publish identical
//!    bits, and unreduced serving forces μ == recall.

use opdr::config::DistConfig;
use opdr::data::{synth, DatasetKind};
use opdr::dist::{Gateway, ThreadWorker, WorkerSpec};
use opdr::index::{AnnIndex, ExactIndex, StorageSpec};
use opdr::metrics::Metric;
use opdr::rpc::{crc32, Fault, FaultProxy, FaultScript};
use opdr::telemetry::registry::{
    PROBE_MU, PROBE_RECALL, PROBE_SAMPLES_TOTAL, WORKER_QUERIES_TOTAL,
};
use opdr::telemetry::Registry;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIM: usize = 8;
const N: usize = 60;
const K: usize = 10;

fn exact_over(rows: &[f32]) -> Arc<dyn AnnIndex> {
    Arc::new(ExactIndex::build(rows, DIM, Metric::SqEuclidean, &StorageSpec::flat(), 7).unwrap())
}

fn dist_cfg(workers: usize, connect_ms: u64, deadline_ms: u64) -> DistConfig {
    DistConfig {
        workers,
        connect_timeout_ms: connect_ms,
        request_deadline_ms: deadline_ms,
        ..Default::default()
    }
}

fn spawn_workers(data: &[f32], n: usize, shards: usize) -> (Vec<ThreadWorker>, Vec<WorkerSpec>) {
    let ranges = opdr::index::shard::shard_ranges(n, shards, 1);
    let workers: Vec<ThreadWorker> = ranges
        .iter()
        .map(|r| {
            ThreadWorker::spawn(exact_over(&data[r.start * DIM..r.end * DIM]), r.start).unwrap()
        })
        .collect();
    let specs = workers
        .iter()
        .enumerate()
        .map(|(i, w)| WorkerSpec::fixed(format!("w{i}"), w.addr()))
        .collect();
    (workers, specs)
}

/// The value of the exposition sample whose `name{labels}` key is exactly
/// `key`.
fn sample(exposition: &str, key: &str) -> Option<f64> {
    exposition.lines().find_map(|l| {
        let (k, v) = l.rsplit_once(' ')?;
        if k == key {
            v.parse().ok()
        } else {
            None
        }
    })
}

/// Scraped snapshots reload bit-for-bit, and the federated exposition's
/// unlabeled aggregates are the exact per-worker sums.
#[test]
fn federated_exposition_matches_per_worker_registries_bit_for_bit() {
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    let (workers, specs) = spawn_workers(set.data(), N, 2);
    let mut gw = Gateway::new(specs, dist_cfg(2, 1000, 2000), Arc::new(Registry::new()));
    let queries = 20usize;
    for i in 0..queries {
        let r = gw.search(set.vector(i % N), K).unwrap();
        assert!(!r.partial, "healthy cluster answered partial");
    }

    // Lossless scrape: reload each worker's snapshot into a fresh registry
    // and compare the rendered exposition bit-for-bit. MetricsPull itself
    // must not perturb the counters it reports, so this also pins the
    // scrape to be a pure read.
    let scraped = gw.scrape_metrics();
    assert_eq!(scraped.len(), 2);
    for (i, (name, snap)) in scraped.iter().enumerate() {
        assert_eq!(name, &format!("w{i}"));
        let snap = snap.as_ref().expect("healthy worker failed the scrape");
        let reloaded = Registry::new();
        reloaded.load_snapshot(snap, &[]).unwrap();
        let local = workers[i].registry().render();
        assert!(!local.is_empty(), "worker registry rendered empty");
        assert_eq!(
            reloaded.render(),
            local,
            "snapshot of w{i} did not reload bit-for-bit"
        );
    }

    // Federated exposition: per-worker labeled series plus exact unlabeled
    // aggregates. Every query fans out to both shards, so each worker
    // served `queries` and the cluster total is their sum.
    let cluster = gw.cluster_metrics();
    let w0 = sample(&cluster, &format!("{WORKER_QUERIES_TOTAL}{{worker=\"w0\"}}"))
        .expect("w0-labeled sample missing");
    let w1 = sample(&cluster, &format!("{WORKER_QUERIES_TOTAL}{{worker=\"w1\"}}"))
        .expect("w1-labeled sample missing");
    let agg = sample(&cluster, WORKER_QUERIES_TOTAL).expect("aggregate sample missing");
    assert_eq!(w0 as usize, queries);
    assert_eq!(w1 as usize, queries);
    assert_eq!(agg, w0 + w1, "aggregate counter must equal the per-worker sum");

    // Federated histogram `_count` is the exact sum; `_sum` merges as
    // exact nanoseconds worker-side, so the rendered seconds agree with
    // the per-worker float sum to rounding.
    let dur = "opdr_worker_query_duration_seconds";
    let c0 = sample(&cluster, &format!("{dur}_count{{worker=\"w0\"}}")).unwrap();
    let c1 = sample(&cluster, &format!("{dur}_count{{worker=\"w1\"}}")).unwrap();
    let cagg = sample(&cluster, &format!("{dur}_count")).unwrap();
    assert_eq!(cagg, c0 + c1, "federated _count must equal the per-worker sum");
    assert_eq!(cagg as usize, 2 * queries);
    let s0 = sample(&cluster, &format!("{dur}_sum{{worker=\"w0\"}}")).unwrap();
    let s1 = sample(&cluster, &format!("{dur}_sum{{worker=\"w1\"}}")).unwrap();
    let sagg = sample(&cluster, &format!("{dur}_sum")).unwrap();
    assert!(
        (sagg - (s0 + s1)).abs() <= 1e-9 * (1.0 + sagg.abs()),
        "federated _sum {sagg} diverged from per-worker sum {}",
        s0 + s1
    );

    // The gateway's own series federate too.
    assert!(
        sample(&cluster, "opdr_rpc_worker_up{worker=\"w0\"}") == Some(1.0),
        "gateway liveness gauge missing from the cluster exposition"
    );
    drop(workers);
}

/// A dead worker degrades the scrape — `worker_up 0`, a scrape-error tick,
/// the live workers' samples intact — instead of failing it.
#[test]
fn dead_worker_degrades_the_scrape_not_the_exposition() {
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    let (mut workers, specs) = spawn_workers(set.data(), N, 2);
    let mut gw = Gateway::new(specs, dist_cfg(2, 200, 400), Arc::new(Registry::new()));
    for i in 0..4 {
        let r = gw.search(set.vector(i), K).unwrap();
        assert!(!r.partial);
    }
    workers[1].kill();
    let cluster = gw.cluster_metrics();
    assert_eq!(
        sample(&cluster, "opdr_rpc_worker_up{worker=\"w1\"}"),
        Some(0.0),
        "dead worker must read worker_up 0:\n{cluster}"
    );
    assert_eq!(
        sample(&cluster, "opdr_rpc_scrape_errors_total{worker=\"w1\"}"),
        Some(1.0),
        "failed scrape must be counted:\n{cluster}"
    );
    // The surviving worker's samples still federate.
    assert_eq!(
        sample(&cluster, &format!("{WORKER_QUERIES_TOTAL}{{worker=\"w0\"}}")),
        Some(4.0),
        "live worker's samples missing:\n{cluster}"
    );
}

/// Which protocol stage the scripted fault lands on (same matrix as
/// `dist_it.rs`).
#[derive(Clone, Copy, Debug)]
enum Target {
    Handshake,
    Request,
    Response,
}

fn scripts_for(target: Target, fault: Fault) -> (FaultScript, FaultScript) {
    match target {
        Target::Handshake => (FaultScript::fault_at(0, fault), FaultScript::clean()),
        Target::Request => (FaultScript::fault_at(1, fault), FaultScript::clean()),
        Target::Response => (FaultScript::clean(), FaultScript::fault_at(1, fault)),
    }
}

/// Trace ids survive every fault × stage: the traced query always lands in
/// the flight recorder with per-shard stage timings from the surviving
/// shards, and every partial answer's entry names the faulted shard.
#[test]
fn trace_ids_survive_the_fault_matrix_and_partials_name_the_faulted_shard() {
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    let data = set.data();
    let ranges = opdr::index::shard::shard_ranges(N, 3, 1);
    let q = set.vector(5);
    let faults = [
        Fault::Drop,
        Fault::Truncate(5),
        Fault::Truncate(25),
        Fault::Delay(700),
        Fault::Duplicate,
        Fault::Reorder,
        Fault::Corrupt(2),
        Fault::Corrupt(30),
    ];
    for target in [Target::Handshake, Target::Request, Target::Response] {
        for fault in faults {
            let case = format!("{target:?}/{fault:?}");
            let workers: Vec<ThreadWorker> = ranges
                .iter()
                .map(|r| {
                    ThreadWorker::spawn(exact_over(&data[r.start * DIM..r.end * DIM]), r.start)
                        .unwrap()
                })
                .collect();
            let (req_script, resp_script) = scripts_for(target, fault);
            let upstream: SocketAddr = workers[0].addr().parse().unwrap();
            let proxy = FaultProxy::spawn(upstream, req_script, resp_script).unwrap();
            let specs = vec![
                WorkerSpec::fixed("w0", proxy.addr().to_string()),
                WorkerSpec::fixed("w1", workers[1].addr()),
                WorkerSpec::fixed("w2", workers[2].addr()),
            ];
            let mut gw = Gateway::new(specs, dist_cfg(3, 400, 150), Arc::new(Registry::new()));
            let t0 = Instant::now();
            let r = gw
                .search(q, K)
                .unwrap_or_else(|e| panic!("{case}: gateway returned an error: {e}"));
            assert!(t0.elapsed() < Duration::from_secs(5), "{case}: query stalled");

            // Trace ids are a per-gateway sequence starting at 1, so the
            // first query's record is addressable without plumbing the id
            // out-of-band.
            let rec = gw
                .recorder()
                .find(1)
                .unwrap_or_else(|| panic!("{case}: traced query never reached the recorder"));
            assert_eq!(rec.k, K, "{case}");
            assert_eq!(rec.shards.len(), 3, "{case}");
            assert_eq!(rec.partial, r.partial, "{case}: recorder disagrees on disposition");

            // The result fingerprint is recomputable from the answer.
            let mut bytes = Vec::new();
            for nb in &r.neighbors {
                bytes.extend_from_slice(&(nb.index as u64).to_le_bytes());
                bytes.extend_from_slice(&nb.distance.to_bits().to_le_bytes());
            }
            assert_eq!(rec.result_checksum, crc32(&bytes), "{case}: checksum mismatch");

            // Surviving shards answered over protocol v2, so their legs
            // must carry worker-reported stage splits; w1/w2 are never
            // faulted.
            for leg in &rec.shards[1..] {
                assert!(leg.ok, "{case}: unfaulted shard {} failed", leg.worker);
                assert!(
                    leg.stages.is_some(),
                    "{case}: surviving shard {} lost its stage timings",
                    leg.worker
                );
            }
            if r.partial {
                // Partial answers must be pinned with the faulted shard
                // named — both in the record and in the dump text.
                let leg = &rec.shards[0];
                assert!(!leg.ok, "{case}: partial answer but shard w0 marked ok");
                assert_eq!(leg.worker, "w0", "{case}");
                assert!(leg.error.is_some(), "{case}: fault disposition missing");
                let dump = gw.recorder().dump();
                assert!(
                    dump.contains("shard worker=w0 FAIL"),
                    "{case}: dump does not name the faulted shard:\n{dump}"
                );
                assert!(dump.contains("[pinned]"), "{case}: partial entry not pinned");
                assert!(
                    dump.contains(&format!("{:#018x}", 1)),
                    "{case}: trace id missing from the dump"
                );
            } else {
                assert!(
                    rec.shards.iter().all(|leg| leg.ok),
                    "{case}: full answer with a failed leg recorded"
                );
            }
            drop(proxy);
            drop(workers);
        }
    }
}

/// With `tracing = false` the gateway sends v1-shaped frames: queries still
/// merge bitwise-exactly, and nothing reaches the recorder.
#[test]
fn tracing_off_sends_v1_frames_and_records_nothing() {
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    let (workers, specs) = spawn_workers(set.data(), N, 2);
    let cfg = DistConfig { tracing: false, ..dist_cfg(2, 1000, 2000) };
    let mut gw = Gateway::new(specs, cfg, Arc::new(Registry::new()));
    let reference = exact_over(set.data());
    for i in 0..5 {
        let r = gw.search(set.vector(i), K).unwrap();
        assert!(!r.partial);
        let expect = reference.search(set.vector(i), K).unwrap();
        assert!(r
            .neighbors
            .iter()
            .zip(&expect)
            .all(|(a, b)| a.index == b.index && a.distance.to_bits() == b.distance.to_bits()));
    }
    assert_eq!(gw.recorder().recorded_total(), 0, "untraced queries were recorded");
    drop(workers);
}

/// The recall probe over a 2-worker gateway: deterministic sampling, gauges
/// recomputed identically across two identical runs, and μ == recall
/// bit-for-bit because distributed serving is unreduced.
#[test]
fn recall_probe_is_deterministic_through_a_two_worker_gateway() {
    let run = || {
        let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
        let (workers, specs) = spawn_workers(set.data(), N, 2);
        let registry = Arc::new(Registry::new());
        let mut gw = Gateway::new(specs, dist_cfg(2, 1000, 2000), Arc::clone(&registry));
        gw.attach_probe("demo", Arc::new(set.data().to_vec()), DIM, Metric::SqEuclidean, 3);
        for i in 0..30 {
            let r = gw.search(set.vector(i % N), K).unwrap();
            assert!(!r.partial);
        }
        // Drain the probe queue so the gauges are final.
        gw.detach_probe();
        let labels = [("collection", "demo")];
        let samples = registry.counter(PROBE_SAMPLES_TOTAL, &labels).get();
        let recall = registry.gauge(PROBE_RECALL, &labels).get();
        let mu = registry.gauge(PROBE_MU, &labels).get();
        drop(workers);
        (samples, recall, mu)
    };
    let (samples, recall, mu) = run();
    assert_eq!(samples, 10, "every=3 over 30 queries must sample exactly 10");
    assert_eq!(recall, 1.0, "exact distributed serving must have recall 1");
    assert_eq!(
        mu.to_bits(),
        recall.to_bits(),
        "unreduced serving must force μ == recall bit-for-bit"
    );
    let rerun = run();
    assert_eq!(
        (samples, recall.to_bits(), mu.to_bits()),
        (rerun.0, rerun.1.to_bits(), rerun.2.to_bits()),
        "probe gauges diverged across identical runs"
    );
}
