//! Observability end-to-end: the labeled registry counts real work, the
//! Prometheus exposition and the legacy `stats` line agree (they are two
//! views over the same storage), and the background recall probe's published
//! gauges match an offline exact recomputation bit-for-bit.

use opdr::config::ServeConfig;
use opdr::coordinator::Coordinator;
use opdr::data::{synth, DatasetKind};
use opdr::metrics::Metric;
use opdr::telemetry::registry;

/// Pull the integer after `key` on the line starting with `prefix`.
fn parse_key(stats: &str, prefix: &str, key: &str) -> u64 {
    let line = stats
        .lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no line starting with {prefix:?} in {stats:?}"));
    line.split(key)
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no {key:?} on {line:?}"))
}

/// Satellite: the once-dead pipeline counters (`vectors_scored`, `batches`,
/// `exec_latency`) now count real work, the per-stage and per-verb series
/// show up in the exposition, and the `Metrics` admin verb renders them.
#[test]
fn metrics_registry_counts_real_work() {
    let cfg = ServeConfig { workers: 2, max_batch: 16, max_wait_ms: 1, ..Default::default() };
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("c", 32, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::OmniCorpus, 300, 32, 11);
    coord.ingest("c", set.data().to_vec()).unwrap();
    for qi in 0..30 {
        let res = coord.search("c", set.vector(qi).to_vec(), 5).unwrap();
        assert_eq!(res.neighbors[0].index, qi);
    }

    let m = coord.metrics();
    assert_eq!(m.completed.get(), 30);
    assert!(m.batches.get() > 0, "batches counter still dead");
    assert!(
        m.vectors_scored.get() >= 30 * 300,
        "vectors_scored counter still dead: {}",
        m.vectors_scored.get()
    );
    assert!(m.exec_latency.count() > 0, "exec_latency histogram still dead");
    assert_eq!(m.queue_wait.count(), 30, "queue-wait span must cover every search");
    assert_eq!(m.latency.count(), 30);
    // Unindexed path: every query runs the flat scan stage, nothing reranks.
    assert_eq!(m.trace.scan.count(), 30);
    assert_eq!(m.trace.rerank.count(), 0);

    // The exposition renders the same storage: summary quantiles for the
    // per-(verb, collection) request series, the stage series, the verb
    // counters, and the topology gauges.
    let text = coord.metrics_text().unwrap();
    assert!(text.contains("# TYPE opdr_request_duration_seconds summary"), "{text}");
    let series = "opdr_request_duration_seconds{collection=\"c\",verb=\"search\"";
    assert!(text.contains(&format!("{series},quantile=\"0.5\"}}")), "{text}");
    assert!(text.contains(&format!("{series},quantile=\"0.999\"}}")), "{text}");
    assert!(text.contains("opdr_requests_total{collection=\"c\",verb=\"search\"} 30"), "{text}");
    assert!(
        text.contains("opdr_stage_duration_seconds{stage=\"scan\",quantile=\"0.99\"}"),
        "{text}"
    );
    assert!(text.contains("opdr_stage_duration_seconds{stage=\"queue_wait\""), "{text}");
    assert!(text.contains("opdr_collection_rows{collection=\"c\"} 300"), "{text}");
    // Admin verbs get their own series too (counted at dispatch).
    assert!(text.contains("opdr_requests_total{collection=\"c\",verb=\"ingest\"} 1"), "{text}");
    assert!(
        text.contains("opdr_request_duration_seconds{collection=\"_admin\",verb=\"metrics\""),
        "{text}"
    );
    coord.shutdown();
}

/// Satellite (stats backward compat): the legacy `stats` line is a view over
/// the registry — its `shards=` / `delta=` / `n=` keys and its summary
/// counters must agree with the gauge/counter read-back and the exposition.
#[test]
fn stats_line_and_registry_agree() {
    let dim = 12;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait_ms: 1,
        index_kind: opdr::index::IndexKind::Exact,
        ivf_threshold: 0,
        shards: 4,
        shard_min_vectors: 1,
        delta_max_vectors: 1000, // keep the delta un-compacted
        ..Default::default()
    };
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("c", dim, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::MaterialsStable, 140, dim, 23);
    coord.ingest("c", set.data()[..120 * dim].to_vec()).unwrap();
    coord.build_index("c").unwrap();
    coord.ingest("c", set.data()[120 * dim..].to_vec()).unwrap();
    for qi in 0..10 {
        coord.search("c", set.vector(qi).to_vec(), 3).unwrap();
    }

    let stats = coord.stats().unwrap();
    let n = parse_key(&stats, "collection c:", "n=");
    let shards = parse_key(&stats, "collection c:", "shards=");
    let delta = parse_key(&stats, "collection c:", "delta=");
    assert_eq!((n, shards, delta), (140, 4, 20), "{stats}");

    // Gauge read-back (refreshed by the stats call itself) agrees.
    let reg = &coord.metrics().registry;
    let lbl = [("collection", "c")];
    assert_eq!(reg.gauge(registry::COLLECTION_ROWS, &lbl).get(), 140.0);
    assert_eq!(reg.gauge(registry::COLLECTION_SHARDS, &lbl).get(), 4.0);
    assert_eq!(reg.gauge(registry::COLLECTION_DELTA_ROWS, &lbl).get(), 20.0);

    // Summary counters in the legacy line are the registered instruments.
    let completed = parse_key(&stats, "requests=", "completed=");
    assert_eq!(completed, coord.metrics().completed.get());
    let requests = parse_key(&stats, "requests=", "requests=");
    assert_eq!(requests, coord.metrics().requests.get());

    // And the exposition shows the same topology values.
    let text = coord.metrics_text().unwrap();
    assert!(text.contains("opdr_collection_shards{collection=\"c\"} 4"), "{text}");
    assert!(text.contains("opdr_collection_delta_rows{collection=\"c\"} 20"), "{text}");
    assert!(text.contains("opdr_collection_rows{collection=\"c\"} 140"), "{text}");
    coord.shutdown();
}

/// Tentpole acceptance: the background recall probe's `recall@k` gauge must
/// equal an offline exact recomputation over the same served results —
/// deterministic sampling (every query here) plus exact shadow scans leave
/// no room for drift. Served without reduction, the serving space equals the
/// full space, so the order-preserving measure μ must equal recall exactly.
#[test]
fn recall_probe_matches_offline_exact_computation() {
    let dim = 24;
    let n = 400;
    let k = 10;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait_ms: 1,
        ivf_threshold: 100,
        ivf_nlist: 16,
        ivf_nprobe: 2, // genuinely approximate → recall < 1 is expected
        recall_probe: true,
        recall_probe_every: 1, // shadow-execute every query
        ..Default::default()
    };
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("p", dim, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Flickr30k, n, dim, 61);
    coord.ingest("p", set.data().to_vec()).unwrap();
    coord.build_index("p").unwrap();

    let queries = 25;
    let mut recall_sum = 0.0f64;
    for qi in 0..queries {
        let res = coord.search("p", set.vector(qi).to_vec(), k).unwrap();
        // Offline ground truth through the same exact-KNN kernel the probe
        // uses, over the same rows.
        let exact: std::collections::HashSet<usize> =
            opdr::knn::knn_indices(set.vector(qi), set.data(), dim, k, Metric::SqEuclidean)
                .unwrap()
                .into_iter()
                .map(|nb| nb.index)
                .collect();
        let hits = res.neighbors.iter().filter(|nb| exact.contains(&nb.index)).count();
        recall_sum += hits as f64 / k.min(n).max(1) as f64;
    }
    let expected = recall_sum / queries as f64;

    // The probe evaluates asynchronously; its channel is drained in order,
    // so poll until all samples landed.
    let reg = std::sync::Arc::clone(&coord.metrics().registry);
    let lbl = [("collection", "p")];
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    while reg.counter(registry::PROBE_SAMPLES_TOTAL, &lbl).get() < queries as u64 {
        assert!(
            std::time::Instant::now() < deadline,
            "probe only evaluated {} of {queries} samples",
            reg.counter(registry::PROBE_SAMPLES_TOTAL, &lbl).get()
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let recall = reg.gauge(registry::PROBE_RECALL, &lbl).get();
    let mu = reg.gauge(registry::PROBE_MU, &lbl).get();
    assert!(
        (recall - expected).abs() < 1e-12,
        "probe recall@{k} {recall} != offline exact {expected}"
    );
    assert!(
        (mu - recall).abs() < 1e-12,
        "unreduced serving space: μ {mu} must equal recall {recall}"
    );
    assert!(recall > 0.0, "probe published a zero recall");

    // The gauges appear in the exposition with the collection label.
    let text = coord.metrics_text().unwrap();
    assert!(text.contains("opdr_probe_recall_at_k{collection=\"p\"}"), "{text}");
    assert!(text.contains("opdr_probe_op_measure_mu{collection=\"p\"}"), "{text}");
    assert!(text.contains("opdr_probe_samples_total{collection=\"p\"} 25"), "{text}");
    coord.shutdown();
}
