//! Integration tests: coordinator end-to-end, including the PJRT path.

use opdr::config::ServeConfig;
use opdr::coordinator::Coordinator;
use opdr::data::{synth, DatasetKind};
use opdr::index::AnnIndex as _;
use opdr::metrics::Metric;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.toml").exists()
}

#[test]
fn full_lifecycle_with_reduction_and_recall() {
    let cfg = ServeConfig { workers: 2, max_batch: 16, max_wait_ms: 1, ..Default::default() };
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("lib", 128, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::MaterialsObservable, 300, 128, 9);
    coord.ingest("lib", set.data().to_vec()).unwrap();

    // Ground truth at full dim for 20 queries.
    let k = 10;
    let mut truth = Vec::new();
    for qi in 0..20 {
        let q = set.vector(qi);
        truth.push(
            opdr::knn::knn_indices(q, set.data(), 128, k, Metric::SqEuclidean).unwrap(),
        );
    }

    let planned = coord.build_reduced("lib", 0.9, k).unwrap();
    assert!(planned < 128, "OPDR should reduce below full dim, got {planned}");

    // Recall of reduced serving vs full-dim ground truth.
    let mut hits = 0usize;
    for (qi, t) in truth.iter().enumerate() {
        let res = coord.search("lib", set.vector(qi).to_vec(), k).unwrap();
        assert_eq!(res.scored_dim, planned);
        let got: std::collections::HashSet<usize> =
            res.neighbors.iter().map(|n| n.index).collect();
        hits += t.iter().filter(|n| got.contains(&n.index)).count();
    }
    let recall = hits as f64 / (20 * k) as f64;
    assert!(recall > 0.6, "recall@{k} = {recall} too low for target 0.9");
    coord.shutdown();
}

#[test]
fn runtime_path_agrees_with_cpu_path() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let set = synth::generate(DatasetKind::Flickr30k, 400, 96, 4);
    let k = 8;

    let run = |use_runtime: bool| -> Vec<Vec<usize>> {
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait_ms: 1,
            use_runtime,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("c", 96, Metric::SqEuclidean).unwrap();
        coord.ingest("c", set.data().to_vec()).unwrap();
        let mut out = Vec::new();
        for qi in 0..12 {
            let res = coord.search("c", set.vector(qi).to_vec(), k).unwrap();
            out.push(res.neighbors.iter().map(|n| n.index).collect());
        }
        coord.shutdown();
        out
    };

    let cpu = run(false);
    let rt = run(true);
    assert_eq!(cpu, rt, "PJRT and CPU scoring disagree");
}

#[test]
fn concurrent_clients_under_load() {
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 32,
        max_wait_ms: 2,
        queue_capacity: 4096,
        ..Default::default()
    };
    let coord = std::sync::Arc::new(Coordinator::start(cfg).unwrap());
    coord.create_collection("c", 32, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::OmniCorpus, 500, 32, 5);
    coord.ingest("c", set.data().to_vec()).unwrap();

    let mut handles = Vec::new();
    for t in 0..4 {
        let coord = std::sync::Arc::clone(&coord);
        let set = set.clone();
        handles.push(std::thread::spawn(move || {
            let mut ok = 0;
            for i in 0..50 {
                let qi = (t * 50 + i) % 500;
                if let Ok(res) = coord.search("c", set.vector(qi).to_vec(), 5) {
                    assert_eq!(res.neighbors[0].index, qi); // self-hit
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 200);
    assert_eq!(coord.metrics().completed.get(), 200);
    // Batching must actually have batched (fewer batches than requests).
    assert!(coord.metrics().batches.get() < 200, "no batching happened");
}

#[test]
fn admin_errors_propagate_to_caller() {
    let coord = Coordinator::start(ServeConfig::default()).unwrap();
    assert!(coord.ingest("missing", vec![0.0; 4]).is_err());
    assert!(coord.build_reduced("missing", 0.9, 5).is_err());
    coord.create_collection("c", 4, Metric::Euclidean).unwrap();
    assert!(coord.create_collection("c", 4, Metric::Euclidean).is_err());
    assert!(coord.ingest("c", vec![0.0; 3]).is_err()); // ragged
    coord.shutdown();
}

/// Tentpole acceptance: `BuildReduced` → HNSW-indexed search where the
/// substrate is selected by the config-driven `IndexPolicy` (parsed from
/// TOML, not constructed in code), with recall@10 ≥ 0.9 against exact KNN
/// over the same reduced space.
#[test]
fn build_reduced_with_hnsw_policy_reaches_recall() {
    let n = 500;
    let dim = 64;
    let k = 10;
    // Synthetic multimodal collection (Flickr30k regime: image+text concat).
    let set = synth::generate(DatasetKind::Flickr30k, n, dim, 21);

    // Run the same deterministic pipeline under two configs that differ only
    // in indexing: HNSW policy vs. no index (exact scan over the identical
    // reduced space, since BuildReduced seeds are fixed inside the server).
    let run = |toml: &str| -> Vec<Vec<usize>> {
        let cfg = opdr::config::ServeConfig::from_toml_str(toml).unwrap();
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("mm", dim, Metric::SqEuclidean).unwrap();
        coord.ingest("mm", set.data().to_vec()).unwrap();
        let planned = coord.build_reduced("mm", 0.9, k).unwrap();
        assert!(planned >= 1 && planned <= dim);
        let mut out = Vec::new();
        for qi in 0..40 {
            let res = coord.search("mm", set.vector(qi).to_vec(), k).unwrap();
            assert_eq!(res.scored_dim, planned);
            out.push(res.neighbors.iter().map(|nb| nb.index).collect());
        }
        coord.shutdown();
        out
    };

    let hnsw_toml = "[serve]\nworkers = 2\nmax_batch = 8\nmax_wait_ms = 1\n\
                     ivf_threshold = 100\nindex_kind = \"hnsw\"\nhnsw_ef_search = 128\n";
    let exact_toml = "[serve]\nworkers = 2\nmax_batch = 8\nmax_wait_ms = 1\n\
                      ivf_threshold = 1000000\n";
    let hnsw = run(hnsw_toml);
    let exact = run(exact_toml);

    let mut hits = 0usize;
    for (h, e) in hnsw.iter().zip(&exact) {
        let got: std::collections::HashSet<usize> = h.iter().copied().collect();
        hits += e.iter().filter(|i| got.contains(*i)).count();
    }
    let recall = hits as f64 / (40 * k) as f64;
    assert!(recall >= 0.9, "hnsw recall@{k} vs exact = {recall}");

    // The config-selected substrate must actually be HNSW.
    let cfg = opdr::config::ServeConfig::from_toml_str(hnsw_toml).unwrap();
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("mm", dim, Metric::SqEuclidean).unwrap();
    coord.ingest("mm", set.data().to_vec()).unwrap();
    coord.build_reduced("mm", 0.9, k).unwrap();
    let stats = coord.stats().unwrap();
    assert!(stats.contains("kind=hnsw"), "{stats}");
    coord.shutdown();
}

/// Tentpole acceptance: an HNSW+SQ8 index survives a save/load round-trip
/// with bit-identical search results, served through the coordinator.
#[test]
fn hnsw_sq8_index_survives_restart_bit_identical() {
    let n = 300;
    let dim = 32;
    let k = 8;
    let set = synth::generate(DatasetKind::Esc50, n, dim, 13);
    let dir = std::env::temp_dir().join(format!("opdr_it_idx_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mm.opdx");
    let path_str = path.to_str().unwrap();

    let cfg = ServeConfig {
        workers: 2,
        ivf_threshold: 50,
        index_kind: opdr::index::IndexKind::Hnsw,
        index_sq8: true,
        hnsw_ef_search: 96,
        ..Default::default()
    };

    // First "process": build, search, persist.
    let before: Vec<Vec<(usize, u32)>>;
    {
        let coord = Coordinator::start(cfg.clone()).unwrap();
        coord.create_collection("mm", dim, Metric::SqEuclidean).unwrap();
        coord.ingest("mm", set.data().to_vec()).unwrap();
        coord.build_index("mm").unwrap();
        let stats = coord.stats().unwrap();
        assert!(stats.contains("kind=hnsw") && stats.contains("quantized=true"), "{stats}");
        before = (0..20)
            .map(|qi| {
                coord
                    .search("mm", set.vector(qi).to_vec(), k)
                    .unwrap()
                    .neighbors
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect()
            })
            .collect();
        coord.save_index("mm", path_str).unwrap();
        coord.shutdown();
    }

    // Second "process": same data, index loaded from disk instead of rebuilt.
    {
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("mm", dim, Metric::SqEuclidean).unwrap();
        coord.ingest("mm", set.data().to_vec()).unwrap();
        coord.load_index("mm", path_str).unwrap();
        for (qi, want) in before.iter().enumerate() {
            let got: Vec<(usize, u32)> = coord
                .search("mm", set.vector(qi).to_vec(), k)
                .unwrap()
                .neighbors
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            assert_eq!(&got, want, "query {qi} diverged after reload");
        }
        // Loading into a mismatched collection must fail loudly.
        coord.create_collection("other", dim + 1, Metric::SqEuclidean).unwrap();
        coord.ingest("other", vec![0.0; (dim + 1) * 10]).unwrap();
        assert!(coord.load_index("other", path_str).is_err());
        coord.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Swap-safety: searcher threads hammer an indexed collection while the
/// index is rebuilt (atomic swap) several times. With an exact sharded
/// substrate, the old index and every rebuilt index serve byte-identical
/// rankings (deterministic build over unchanged data), so *every* response
/// must equal the ground truth computed through the same exact-scan kernel:
/// any deviation means a search observed a half-built or stale index, and
/// no search may ever error.
#[test]
fn searches_never_observe_half_built_index_during_swap() {
    let n = 400;
    let dim = 16;
    let k = 6;
    let cfg = ServeConfig {
        workers: 3,
        max_batch: 16,
        max_wait_ms: 1,
        queue_capacity: 4096,
        index_kind: opdr::index::IndexKind::Exact,
        ivf_threshold: 0,
        shards: 4,
        shard_min_vectors: 1,
        ..Default::default()
    };
    let coord = std::sync::Arc::new(Coordinator::start(cfg).unwrap());
    coord.create_collection("c", dim, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Flickr30k, n, dim, 77);
    coord.ingest("c", set.data().to_vec()).unwrap();
    // Install the index before any searcher starts: the unindexed scan uses
    // the matmul-form distance kernel, whose floats differ in the last ulp
    // from the index's direct-form scan, so bitwise assertions are only
    // valid while an index is serving.
    coord.build_index("c").unwrap();

    // Ground truth through the same kernel as the serving index: an
    // unsharded exact scan over the same vectors.
    let exact = opdr::index::ExactIndex::build(
        set.data(),
        dim,
        Metric::SqEuclidean,
        &opdr::index::StorageSpec::flat(),
        1,
    )
    .unwrap();
    let truth: std::sync::Arc<Vec<Vec<(usize, u32)>>> = std::sync::Arc::new(
        (0..n)
            .map(|qi| {
                exact
                    .search(set.vector(qi), k)
                    .unwrap()
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect()
            })
            .collect(),
    );

    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut searchers = Vec::new();
    for t in 0..3usize {
        let coord = std::sync::Arc::clone(&coord);
        let set = set.clone();
        let truth = std::sync::Arc::clone(&truth);
        let stop = std::sync::Arc::clone(&stop);
        searchers.push(std::thread::spawn(move || {
            let mut done = 0usize;
            let mut i = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) || done == 0 {
                let qi = (t * 131 + i * 7) % n;
                i += 1;
                let res = coord
                    .search("c", set.vector(qi).to_vec(), k)
                    .expect("search errored during rebuild");
                let got: Vec<(usize, u32)> = res
                    .neighbors
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                assert_eq!(got, truth[qi], "query {qi} diverged during rebuild");
                done += 1;
            }
            done
        }));
    }

    // Rebuild (atomic swap) repeatedly while the searchers run.
    for _ in 0..5 {
        coord.build_index("c").unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let total: usize = searchers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total >= 3, "searchers made no progress");
    let stats = coord.stats().unwrap();
    assert!(stats.contains("shards=4"), "{stats}");
    coord.shutdown();
}

/// Liveness: `BuildIndex` must not run on the scheduler thread, and its
/// segment builds run on the dedicated build pool, so search work is never
/// queued behind multi-second build jobs. During a long sharded HNSW
/// rebuild, searches against the previously installed index complete
/// *while* the build is in flight, and (same data, same seed) results are
/// byte-identical before, during and after the swap. Timing-sensitive:
/// meaningful in release only (the CI shard/swap job runs it with
/// `--release`).
#[test]
fn build_index_keeps_search_live_while_rebuilding() {
    if cfg!(debug_assertions) {
        eprintln!("SKIP: timing-sensitive swap test runs in release CI");
        return;
    }
    let n = 6000;
    let dim = 32;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 16,
        max_wait_ms: 1,
        queue_capacity: 4096,
        index_kind: opdr::index::IndexKind::Hnsw,
        hnsw_ef_construction: 400,
        ivf_threshold: 0,
        shards: 2,
        shard_min_vectors: 1,
        ..Default::default()
    };
    let coord = std::sync::Arc::new(Coordinator::start(cfg).unwrap());
    coord.create_collection("c", dim, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::OmniCorpus, n, dim, 3);
    coord.ingest("c", set.data().to_vec()).unwrap();

    // First build: blocks the *caller* until the swap, not the scheduler.
    coord.build_index("c").unwrap();
    let expected: Vec<(usize, u32)> = coord
        .search("c", set.vector(9).to_vec(), 8)
        .unwrap()
        .neighbors
        .iter()
        .map(|nb| (nb.index, nb.distance.to_bits()))
        .collect();

    // Second build of the same data (same seed → bit-identical index) on a
    // helper thread; the main thread searches until it completes.
    let building = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(true));
    let builder = {
        let coord = std::sync::Arc::clone(&coord);
        let building = std::sync::Arc::clone(&building);
        std::thread::spawn(move || {
            let started = std::time::Instant::now();
            coord.build_index("c").unwrap();
            building.store(false, std::sync::atomic::Ordering::SeqCst);
            started.elapsed()
        })
    };

    let mut overlapped = 0usize;
    while building.load(std::sync::atomic::Ordering::SeqCst) {
        let res = coord.search("c", set.vector(9).to_vec(), 8).unwrap();
        let got: Vec<(usize, u32)> = res
            .neighbors
            .iter()
            .map(|nb| (nb.index, nb.distance.to_bits()))
            .collect();
        assert_eq!(got, expected, "search diverged during the rebuild");
        if building.load(std::sync::atomic::Ordering::SeqCst) {
            overlapped += 1;
        }
    }
    let build_time = builder.join().unwrap();
    assert!(
        overlapped >= 1,
        "no search completed during a {build_time:?} rebuild — BuildIndex blocked the scheduler"
    );
    // After the swap: still byte-identical (deterministic rebuild).
    let after: Vec<(usize, u32)> = coord
        .search("c", set.vector(9).to_vec(), 8)
        .unwrap()
        .neighbors
        .iter()
        .map(|nb| (nb.index, nb.distance.to_bits()))
        .collect();
    assert_eq!(after, expected);
    coord.shutdown();
}

/// A sharded (version-3, multi-segment) index survives a save/load
/// round-trip through the coordinator's SaveIndex/LoadIndex verbs with
/// bit-identical search results.
#[test]
fn sharded_index_survives_restart_bit_identical() {
    let n = 240;
    let dim = 12;
    let k = 7;
    let set = synth::generate(DatasetKind::MaterialsStable, n, dim, 31);
    let dir = std::env::temp_dir().join(format!("opdr_it_shidx_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sharded.opdx");
    let path_str = path.to_str().unwrap();

    let cfg = ServeConfig {
        workers: 2,
        index_kind: opdr::index::IndexKind::Hnsw,
        index_sq8: true,
        ivf_threshold: 0,
        shards: 3,
        shard_min_vectors: 1,
        ..Default::default()
    };

    let before: Vec<Vec<(usize, u32)>>;
    {
        let coord = Coordinator::start(cfg.clone()).unwrap();
        coord.create_collection("mm", dim, Metric::SqEuclidean).unwrap();
        coord.ingest("mm", set.data().to_vec()).unwrap();
        coord.build_index("mm").unwrap();
        let stats = coord.stats().unwrap();
        assert!(stats.contains("kind=hnsw") && stats.contains("shards=3"), "{stats}");
        before = (0..15)
            .map(|qi| {
                coord
                    .search("mm", set.vector(qi).to_vec(), k)
                    .unwrap()
                    .neighbors
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect()
            })
            .collect();
        coord.save_index("mm", path_str).unwrap();
        coord.shutdown();
    }
    {
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("mm", dim, Metric::SqEuclidean).unwrap();
        coord.ingest("mm", set.data().to_vec()).unwrap();
        coord.load_index("mm", path_str).unwrap();
        let stats = coord.stats().unwrap();
        assert!(stats.contains("shards=3"), "{stats}");
        for (qi, want) in before.iter().enumerate() {
            let got: Vec<(usize, u32)> = coord
                .search("mm", set.vector(qi).to_vec(), k)
                .unwrap()
                .neighbors
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            assert_eq!(&got, want, "query {qi} diverged after reload");
        }
        coord.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Poll `coord.stats()` until `pred` holds (compactions finish on the build
/// pool's collector thread, so stats converge asynchronously).
fn wait_for_stats(coord: &Coordinator, pred: impl Fn(&str) -> bool) -> String {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let s = coord.stats().unwrap();
        if pred(&s) {
            return s;
        }
        if std::time::Instant::now() > deadline {
            panic!("stats never converged: {s}");
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

/// Ingest-then-search, incremental path: an ingest after `build_index` must
/// *not* drop the serving index — the rows land in a flat exact delta
/// segment, searches stay bitwise identical to a flat exact scan over the
/// concatenated rows, and once the delta outgrows `delta_max_vectors` a
/// background compaction folds it into a rebuilt main index.
#[test]
fn incremental_ingest_keeps_index_serving_and_compacts() {
    let dim = 12;
    let k = 6;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait_ms: 1,
        use_runtime: false,
        index_kind: opdr::index::IndexKind::Exact,
        ivf_threshold: 0,
        delta_max_vectors: 30,
        build_workers: 1,
        ..Default::default()
    };
    assert!(cfg.incremental_ingest, "incremental ingest is the default");
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("c", dim, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::OmniCorpus, 140, dim, 41);
    coord.ingest("c", set.data()[..100 * dim].to_vec()).unwrap();
    coord.build_index("c").unwrap();

    let flat_over = |rows: usize| {
        opdr::index::ExactIndex::build(
            &set.data()[..rows * dim],
            dim,
            Metric::SqEuclidean,
            &opdr::index::StorageSpec::flat(),
            1,
        )
        .unwrap()
    };
    let check_bitwise = |rows: usize, qis: &[usize]| {
        let flat = flat_over(rows);
        for &qi in qis {
            let want: Vec<(usize, u32)> = flat
                .search(set.vector(qi), k)
                .unwrap()
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            let got: Vec<(usize, u32)> = coord
                .search("c", set.vector(qi).to_vec(), k)
                .unwrap()
                .neighbors
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            assert_eq!(got, want, "query {qi} diverged (n={rows})");
        }
    };

    // Below the compaction bound: the rows are served from the delta.
    coord.ingest("c", set.data()[100 * dim..120 * dim].to_vec()).unwrap();
    let stats = coord.stats().unwrap();
    assert!(
        stats.contains("indexed=true") && stats.contains("delta=20"),
        "ingest must not drop the index: {stats}"
    );
    assert!(stats.contains("kind=exact"), "{stats}");
    check_bitwise(120, &[0, 50, 100, 119]);

    // Past the bound: a background compaction folds the delta in.
    coord.ingest("c", set.data()[120 * dim..].to_vec()).unwrap();
    let stats = wait_for_stats(&coord, |s| {
        s.contains("compactions=1") && s.contains("delta=0") && s.contains("building=0")
    });
    assert!(stats.contains("indexed=true"), "{stats}");
    check_bitwise(140, &[0, 99, 120, 139]);
    coord.shutdown();
}

/// Ingest-then-search, legacy path (`incremental_ingest = false`): the
/// pre-existing invalidate-on-ingest behavior stays available and correct —
/// the index is dropped and searches fall back to the brute scan until the
/// next rebuild.
#[test]
fn legacy_ingest_invalidates_index_and_serves_brute_scan() {
    let dim = 16;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        max_wait_ms: 1,
        use_runtime: false,
        index_kind: opdr::index::IndexKind::Exact,
        ivf_threshold: 0,
        incremental_ingest: false,
        ..Default::default()
    };
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("c", dim, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::MaterialsStable, 90, dim, 57);
    coord.ingest("c", set.data()[..80 * dim].to_vec()).unwrap();
    coord.build_index("c").unwrap();
    assert!(coord.stats().unwrap().contains("indexed=true"));

    coord.ingest("c", set.data()[80 * dim..].to_vec()).unwrap();
    let stats = coord.stats().unwrap();
    assert!(stats.contains("indexed=false"), "legacy ingest must invalidate: {stats}");
    // Brute scan over all 90 rows: old and new rows both found (id-equal;
    // the matmul-form brute kernel rounds differently than the index scan,
    // so bitwise assertions don't apply here).
    for qi in [0usize, 79, 80, 89] {
        let res = coord.search("c", set.vector(qi).to_vec(), 3).unwrap();
        assert_eq!(res.neighbors[0].index, qi, "row {qi} lost after legacy ingest");
    }
    coord.shutdown();
}

/// Compaction race, end to end under load: searcher threads hammer self-hit
/// queries while the main thread streams ingest batches that repeatedly
/// push the delta over the compaction bound. Every acked row must stay
/// findable through every {index, delta} state and across every compaction
/// swap (no row lost, none doubly indexed), and the final state must be
/// bitwise identical to a flat exact scan over everything ingested.
#[test]
fn incremental_ingest_under_search_load_never_loses_rows() {
    let dim = 16;
    let total = 224;
    let base = 64;
    let cfg = ServeConfig {
        workers: 3,
        max_batch: 16,
        max_wait_ms: 1,
        queue_capacity: 4096,
        use_runtime: false,
        index_kind: opdr::index::IndexKind::Exact,
        ivf_threshold: 0,
        delta_max_vectors: 16,
        build_workers: 2,
        ..Default::default()
    };
    let coord = std::sync::Arc::new(Coordinator::start(cfg).unwrap());
    coord.create_collection("c", dim, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Flickr30k, total, dim, 31);
    coord.ingest("c", set.data()[..base * dim].to_vec()).unwrap();
    coord.build_index("c").unwrap();

    let high_water = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(base));
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut searchers = Vec::new();
    for t in 0..2usize {
        let coord = std::sync::Arc::clone(&coord);
        let set = set.clone();
        let high_water = std::sync::Arc::clone(&high_water);
        let stop = std::sync::Arc::clone(&stop);
        searchers.push(std::thread::spawn(move || {
            let mut done = 0usize;
            let mut i = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) || done == 0 {
                let hw = high_water.load(std::sync::atomic::Ordering::Acquire);
                let qi = (t * 131 + i * 7) % hw;
                i += 1;
                let res = coord
                    .search("c", set.vector(qi).to_vec(), 4)
                    .expect("search errored during incremental ingest");
                assert_eq!(
                    res.neighbors[0].index, qi,
                    "acked row {qi} not served (hw={hw})"
                );
                done += 1;
            }
            done
        }));
    }

    // Stream the remaining rows in batches of 8; every batch is acked
    // before the high-water mark advances, so searchers only query rows the
    // coordinator has confirmed.
    let mut at = base;
    while at < total {
        let end = (at + 8).min(total);
        coord.ingest("c", set.data()[at * dim..end * dim].to_vec()).unwrap();
        high_water.store(end, std::sync::atomic::Ordering::Release);
        at = end;
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let completed: usize = searchers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(completed >= 2, "searchers made no progress");

    // Quiesce: all compactions finished, at least one landed, and the final
    // state serves every row bitwise-exactly.
    let stats = wait_for_stats(&coord, |s| s.contains("building=0"));
    let compactions: u64 = stats
        .split("compactions=")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    assert!(compactions >= 1, "no compaction ever landed: {stats}");
    let flat = opdr::index::ExactIndex::build(
        set.data(),
        dim,
        Metric::SqEuclidean,
        &opdr::index::StorageSpec::flat(),
        1,
    )
    .unwrap();
    for qi in (0..total).step_by(13).chain([base - 1, base, total - 1]) {
        let want: Vec<(usize, u32)> = flat
            .search(set.vector(qi), 5)
            .unwrap()
            .iter()
            .map(|nb| (nb.index, nb.distance.to_bits()))
            .collect();
        let got: Vec<(usize, u32)> = coord
            .search("c", set.vector(qi).to_vec(), 5)
            .unwrap()
            .neighbors
            .iter()
            .map(|nb| (nb.index, nb.distance.to_bits()))
            .collect();
        assert_eq!(got, want, "row {qi} diverged in the final state");
    }
    coord.shutdown();
}

#[test]
fn ivf_index_served_collection() {
    let cfg = ServeConfig {
        workers: 2,
        ivf_threshold: 100,
        ivf_nlist: 16,
        ivf_nprobe: 16, // full probe → exact
        ..Default::default()
    };
    let coord = Coordinator::start(cfg).unwrap();
    coord.create_collection("big", 16, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::MaterialsMetal, 600, 16, 6);
    coord.ingest("big", set.data().to_vec()).unwrap();
    coord.build_index("big").unwrap();
    let res = coord.search("big", set.vector(7).to_vec(), 5).unwrap();
    assert_eq!(res.neighbors[0].index, 7);
    let stats = coord.stats().unwrap();
    assert!(stats.contains("indexed=true"), "{stats}");
    coord.shutdown();
}
