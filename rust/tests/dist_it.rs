//! Distributed-serving integration tests — the fault-injection harness the
//! tentpole guarantee is machine-checked under.
//!
//! The contract: **every gateway query terminates within its deadline with
//! either a bitwise order-exact top-k or a typed partial/degraded result —
//! never a panic, a hang, or a silently wrong ranking.** The matrix test
//! drives a deterministic [`FaultProxy`] through every fault kind
//! (drop / truncate / delay / duplicate / reorder / corrupt) × every
//! protocol stage (handshake / request / response) and asserts exactly
//! that, plus that the very next query *heals* back to the full bitwise
//! answer through a reconnect. The crash test kills a worker mid-storm and
//! proves supervised respawn: degraded serving while down, mmap shard
//! reload on restart, and a post-respawn answer bitwise identical to the
//! pre-crash one.

use opdr::config::DistConfig;
use opdr::data::{store, synth, DatasetKind};
use opdr::dist::{AddrCell, Gateway, Supervisor, ThreadWorker, WorkerHandle, WorkerSpec};
use opdr::index::{AnnIndex, ExactIndex, StorageSpec};
use opdr::knn::Neighbor;
use opdr::metrics::Metric;
use opdr::rpc::{Fault, FaultProxy, FaultScript};
use opdr::telemetry::registry::{RPC_WORKER_RESTARTS, RPC_WORKER_UP};
use opdr::telemetry::Registry;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const DIM: usize = 8;
const N: usize = 60;
const K: usize = 10;

fn exact_over(rows: &[f32]) -> Arc<dyn AnnIndex> {
    Arc::new(ExactIndex::build(rows, DIM, Metric::SqEuclidean, &StorageSpec::flat(), 7).unwrap())
}

fn bits(nbs: &[Neighbor]) -> Vec<(usize, u32)> {
    nbs.iter().map(|nb| (nb.index, nb.distance.to_bits())).collect()
}

fn dist_cfg(workers: usize, connect_ms: u64, deadline_ms: u64) -> DistConfig {
    DistConfig {
        workers,
        connect_timeout_ms: connect_ms,
        request_deadline_ms: deadline_ms,
        ..Default::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("opdr_dist_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Healthy cluster: the gateway answer is bitwise identical to the
/// unsharded exact scan, never partial.
#[test]
fn gateway_matches_unsharded_reference_when_healthy() {
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    let data = set.data();
    let ranges = opdr::index::shard::shard_ranges(N, 3, 1);
    let workers: Vec<ThreadWorker> = ranges
        .iter()
        .map(|r| {
            ThreadWorker::spawn(exact_over(&data[r.start * DIM..r.end * DIM]), r.start).unwrap()
        })
        .collect();
    let specs = workers
        .iter()
        .enumerate()
        .map(|(i, w)| WorkerSpec::fixed(format!("w{i}"), w.addr()))
        .collect();
    let reference = exact_over(data);
    let mut gw = Gateway::new(specs, dist_cfg(3, 1000, 2000), Arc::new(Registry::new()));
    for qi in [0usize, 7, 31, N - 1] {
        for k in [1usize, K, N + 5] {
            let r = gw.search(set.vector(qi), k).unwrap();
            assert!(!r.partial, "healthy cluster answered partial");
            assert_eq!(r.shards_ok, r.shards_total);
            assert_eq!(
                bits(&r.neighbors),
                bits(&reference.search(set.vector(qi), k).unwrap()),
                "qi={qi} k={k}: gateway diverged from the unsharded scan"
            );
        }
    }
    // A NaN query is typed empty on both sides, not a panic.
    let nan_q = vec![f32::NAN; DIM];
    let r = gw.search(&nan_q, K).unwrap();
    assert!(r.neighbors.is_empty() && !r.partial);
}

/// Which protocol stage the scripted fault lands on.
#[derive(Clone, Copy, Debug)]
enum Target {
    /// Client→worker frame 0: the `Hello`.
    Handshake,
    /// Client→worker frame 1: the first `Search`.
    Request,
    /// Worker→client frame 1: the first `SearchOk` (frame 0 is the
    /// `HelloAck`).
    Response,
}

fn scripts_for(target: Target, fault: Fault) -> (FaultScript, FaultScript) {
    match target {
        Target::Handshake => (FaultScript::fault_at(0, fault), FaultScript::clean()),
        Target::Request => (FaultScript::fault_at(1, fault), FaultScript::clean()),
        Target::Response => (FaultScript::clean(), FaultScript::fault_at(1, fault)),
    }
}

/// The headline matrix: every fault × every stage, injected by the
/// deterministic proxy in front of shard 0. Each query must terminate
/// promptly with either the full bitwise answer or a typed partial one
/// that is itself the bitwise order-exact merge of the surviving shards —
/// and the next queries must heal back to the full answer via reconnect.
#[test]
fn fault_matrix_terminates_with_exact_or_typed_partial() {
    let set = synth::generate(DatasetKind::Flickr30k, N, DIM, 42);
    let data = set.data();
    let ranges = opdr::index::shard::shard_ranges(N, 3, 1);
    assert_eq!(ranges.len(), 3);
    let q = set.vector(5);
    let reference = exact_over(data);
    let expect_full = bits(&reference.search(q, K).unwrap());
    // Shard 0 is the faulted one; the only legal degraded answer is the
    // order-exact merge of shards 1..3 = the exact scan over their rows,
    // re-based to global ids.
    let survivors = exact_over(&data[ranges[1].start * DIM..]);
    let expect_survivors: Vec<(usize, u32)> = survivors
        .search(q, K)
        .unwrap()
        .iter()
        .map(|nb| (nb.index + ranges[1].start, nb.distance.to_bits()))
        .collect();

    // Frame sizes here: Hello = 26 bytes, Search(dim 8) = 66 bytes — so
    // Truncate(5) cuts inside the header, Truncate(25) inside the payload,
    // Corrupt(2) flips a magic byte, Corrupt(30) flips payload (CRC trips).
    let faults = [
        Fault::Drop,
        Fault::Truncate(5),
        Fault::Truncate(25),
        Fault::Delay(700),
        Fault::Duplicate,
        Fault::Reorder,
        Fault::Corrupt(2),
        Fault::Corrupt(30),
    ];
    for target in [Target::Handshake, Target::Request, Target::Response] {
        for fault in faults {
            let case = format!("{target:?}/{fault:?}");
            let workers: Vec<ThreadWorker> = ranges
                .iter()
                .map(|r| {
                    ThreadWorker::spawn(exact_over(&data[r.start * DIM..r.end * DIM]), r.start)
                        .unwrap()
                })
                .collect();
            let (req_script, resp_script) = scripts_for(target, fault);
            let upstream: SocketAddr = workers[0].addr().parse().unwrap();
            let proxy = FaultProxy::spawn(upstream, req_script, resp_script).unwrap();
            let specs = vec![
                WorkerSpec::fixed("w0", proxy.addr().to_string()),
                WorkerSpec::fixed("w1", workers[1].addr()),
                WorkerSpec::fixed("w2", workers[2].addr()),
            ];
            // Deadlines well under Delay(700): the delayed frame must trip
            // the deadline, not stall the query.
            let mut gw = Gateway::new(specs, dist_cfg(3, 400, 150), Arc::new(Registry::new()));
            let t0 = Instant::now();
            let r = gw
                .search(q, K)
                .unwrap_or_else(|e| panic!("{case}: gateway returned an error: {e}"));
            let elapsed = t0.elapsed();
            assert!(elapsed < Duration::from_secs(5), "{case}: query took {elapsed:?}");
            if r.partial {
                assert_eq!(r.shards_ok, 2, "{case}: wrong surviving-shard count");
                assert_eq!(
                    bits(&r.neighbors),
                    expect_survivors,
                    "{case}: degraded answer is not the survivors' order-exact merge"
                );
            } else {
                assert_eq!(
                    bits(&r.neighbors),
                    expect_full,
                    "{case}: unflagged answer diverged from the unsharded scan"
                );
            }
            // Heal: the script is spent, so a reconnect through the same
            // proxy must restore the full bitwise answer promptly.
            let heal0 = Instant::now();
            let mut healed = false;
            while heal0.elapsed() < Duration::from_secs(10) {
                let r2 = gw
                    .search(q, K)
                    .unwrap_or_else(|e| panic!("{case}: heal query errored: {e}"));
                if !r2.partial {
                    assert_eq!(bits(&r2.neighbors), expect_full, "{case}: healed but inexact");
                    healed = true;
                    break;
                }
                std::thread::sleep(Duration::from_millis(25));
            }
            assert!(healed, "{case}: never healed back to a full result");
        }
    }
}

/// Every shard unreachable: the query still returns — a typed degraded
/// empty result, promptly — instead of an error or a hang.
#[test]
fn all_workers_down_is_typed_degraded_not_an_error() {
    // Bind-then-drop guarantees the ports are dead (connection refused).
    let dead: Vec<String> = (0..2)
        .map(|_| {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        })
        .collect();
    let specs = dead
        .iter()
        .enumerate()
        .map(|(i, a)| WorkerSpec::fixed(format!("w{i}"), a.clone()))
        .collect();
    let mut gw = Gateway::new(specs, dist_cfg(2, 200, 200), Arc::new(Registry::new()));
    let q = vec![0.5f32; DIM];
    for _ in 0..2 {
        let t0 = Instant::now();
        let r = gw.search(&q, K).unwrap();
        assert!(r.partial, "all-down must be flagged partial");
        assert_eq!(r.shards_ok, 0);
        assert_eq!(r.shards_total, 2);
        assert!(r.neighbors.is_empty());
        assert!(t0.elapsed() < Duration::from_secs(5), "all-down query stalled");
    }
}

/// Crash/restart under supervision: kill a worker mid-storm, every query
/// still returns (degraded while down — no lost or hung client), the
/// supervisor respawns it from its version-5 cold file (mmap reload), and
/// the next full answer is bitwise identical to the pre-crash one.
#[test]
fn worker_crash_mid_storm_respawns_and_heals_bitwise() {
    let n = 80;
    let set = synth::generate(DatasetKind::Flickr30k, n, DIM, 17);
    let data = set.data();
    let ranges = opdr::index::shard::shard_ranges(n, 2, 1);
    let dir = tmp_dir("crash");
    let paths: Vec<PathBuf> = ranges
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let rows = &data[r.start * DIM..r.end * DIM];
            let shard =
                ExactIndex::build(rows, DIM, Metric::SqEuclidean, &StorageSpec::flat(), 7)
                    .unwrap();
            let path = dir.join(format!("shard-{i}.opdx"));
            store::save_index_cold(&shard, &path).unwrap();
            path
        })
        .collect();
    // The respawn path really is the mmap path: a cold reload serves its
    // annex mapped in place, not copied to the heap.
    let probe = store::load_index(&paths[0]).unwrap();
    assert!(probe.mapped_bytes() > 0, "cold shard file did not mmap on load");
    drop(probe);

    let registry = Arc::new(Registry::new());
    // The factory parks each incarnation's stop flag here so the test can
    // kill worker 0 out from under its supervisor, exactly like a crash.
    let current_stop: Arc<Mutex<Option<Arc<AtomicBool>>>> = Arc::new(Mutex::new(None));
    let mut sups = Vec::new();
    let mut specs = Vec::new();
    for (i, range) in ranges.iter().enumerate() {
        let name = format!("w{i}");
        let cell = AddrCell::new("");
        let path = paths[i].clone();
        let start = range.start;
        let crash_hook = (i == 0).then(|| Arc::clone(&current_stop));
        let factory = Box::new(move || -> opdr::Result<Box<dyn WorkerHandle>> {
            let w = ThreadWorker::spawn_from_file(path.to_str().unwrap(), start)?;
            if let Some(hook) = &crash_hook {
                *opdr::util::lock_recover(hook) = Some(w.stop_flag());
            }
            Ok(Box::new(w) as Box<dyn WorkerHandle>)
        });
        sups.push(
            Supervisor::start(name.clone(), Arc::clone(&cell), factory, Arc::clone(&registry))
                .unwrap(),
        );
        specs.push(WorkerSpec { name, addr: cell });
    }
    let mut gw = Gateway::new(specs, dist_cfg(2, 500, 500), Arc::clone(&registry));

    let q = set.vector(3);
    let pre = gw.search(q, K).unwrap();
    assert!(!pre.partial, "cluster unhealthy before the crash");
    let pre_bits = bits(&pre.neighbors);

    // Query storm with a crash at iteration 40. Every query must return
    // Ok — full or partial — with no hung client.
    let mut partials = 0usize;
    for i in 0..200 {
        if i == 40 {
            let flag =
                opdr::util::lock_recover(&current_stop).clone().expect("worker 0 never spawned");
            flag.store(true, Ordering::Relaxed);
        }
        let r = gw.search(set.vector(i % n), K).unwrap();
        if r.partial {
            assert_eq!(r.shards_ok, 1, "storm partial lost more than the crashed shard");
            partials += 1;
        }
    }
    assert!(partials >= 1, "the crash was never observed as degraded serving");
    assert!(partials < 200, "the cluster never recovered during the storm");

    // Heal: supervised respawn + gateway re-dial must restore the exact
    // pre-crash answer, bitwise.
    let heal0 = Instant::now();
    let mut healed = false;
    while heal0.elapsed() < Duration::from_secs(10) {
        let r = gw.search(q, K).unwrap();
        if !r.partial {
            assert_eq!(bits(&r.neighbors), pre_bits, "post-respawn answer diverged");
            healed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(healed, "worker never respawned into a healthy cluster");
    assert!(sups[0].restarts() >= 1, "supervisor recorded no respawn");
    assert!(
        registry.counter(RPC_WORKER_RESTARTS, &[("worker", "w0")]).get() >= 1,
        "restart counter not published"
    );
    assert_eq!(
        registry.gauge(RPC_WORKER_UP, &[("worker", "w0")]).get(),
        1.0,
        "liveness gauge not back up"
    );

    for s in &mut sups {
        s.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}
