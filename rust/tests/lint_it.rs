//! The lint gate, as a test: `opdr-lint` must pass clean on the live tree,
//! and every rule must both fire on a bad fixture and stay silent on a good
//! one (with the `// lint:allow(rule)` escape hatch exercised). CI runs the
//! standalone binary as a blocking step; this suite is the same engine
//! in-process, so `cargo test` alone catches a violation or a regressed
//! rule. Removing a rule's fixture here trips the fixture-presence guard in
//! `.github/workflows/ci.yml`.

use std::path::PathBuf;

use opdr_lint::{lint_sources, Finding};

/// Lint one synthetic file at `path` with the given source.
fn lint_one(path: &str, src: &str) -> Vec<Finding> {
    lint_sources(&[(PathBuf::from(path), src.to_string())])
}

fn rule_names(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// the gate itself: the live tree must be clean
// ---------------------------------------------------------------------------

#[test]
fn live_tree_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let scope: Vec<PathBuf> =
        ["src", "tests", "benches"].iter().map(|d| root.join(d)).collect();
    let findings = opdr_lint::lint_paths(&scope).expect("walking the live tree");
    assert!(
        findings.is_empty(),
        "opdr-lint must pass clean on the tree; violations:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

// ---------------------------------------------------------------------------
// per-rule fixture matrix: each rule fires on bad, stays silent on good
// ---------------------------------------------------------------------------

#[test]
fn fixture_no_partial_cmp_ordering() {
    let bad = r#"
fn worst(xs: &mut Vec<(usize, f32)>) {
    xs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
    let _ = xs[0].1.partial_cmp(&xs[1].1).unwrap();
}
"#;
    let findings = lint_one("rust/src/knn/fixture.rs", bad);
    assert_eq!(rule_names(&findings), ["no-partial-cmp-ordering"; 2]);
    assert_eq!(findings[0].line, 3, "diagnostic must carry the offending line");

    let good = r#"
fn worst(xs: &mut Vec<(usize, f32)>) {
    xs.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
}
impl PartialOrd for Item {
    // Definitions (not call chains) of partial_cmp are fine.
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
"#;
    assert!(lint_one("rust/src/knn/fixture.rs", good).is_empty());

    // Content inside string literals and comments never fires.
    let quoted = r##"
// a.partial_cmp(&b).unwrap() used to live here
const DOC: &str = "a.partial_cmp(&b).unwrap()";
"##;
    assert!(lint_one("rust/src/knn/fixture.rs", quoted).is_empty());
}

#[test]
fn fixture_no_naked_lock_unwrap() {
    let bad = r#"
fn stats(m: &std::sync::Mutex<u64>) -> u64 {
    *m.lock().unwrap()
}
fn stats2(m: &std::sync::Mutex<u64>) -> u64 {
    *m.lock().expect("poisoned")
}
"#;
    let findings = lint_one("rust/src/coordinator/fixture.rs", bad);
    assert_eq!(rule_names(&findings), ["no-naked-lock-unwrap"; 2]);
    assert_eq!(findings[0].line, 3);

    // lock_recover (and its own unwrap_or_else implementation) are clean.
    let good = r#"
fn stats(m: &std::sync::Mutex<u64>) -> u64 {
    *crate::util::lock_recover(m)
}
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}
"#;
    assert!(lint_one("rust/src/coordinator/fixture.rs", good).is_empty());
}

#[test]
fn fixture_bounded_prealloc() {
    // Wire-decoded sizes handed straight to allocation, in a decode path.
    let bad = r#"
fn decode(r: &mut dyn Read) -> Vec<u8> {
    let n = read_u32(r).unwrap() as usize;
    let mut header = Vec::with_capacity(n);
    let mut body = vec![0u8; n];
    body
}
"#;
    let findings = lint_one("rust/src/rpc/frame.rs", bad);
    assert_eq!(rule_names(&findings), ["bounded-prealloc"; 2]);
    assert_eq!(findings[0].line, 4);
    assert_eq!(findings[1].line, 5);

    // Clamped through ALLOC_CHUNK or literal-sized: clean.
    let good = r#"
fn decode(r: &mut dyn Read) -> Vec<u8> {
    let n = read_u32(r).unwrap() as usize;
    let mut out = Vec::with_capacity(n.min(ALLOC_CHUNK));
    let scratch = vec![0u8; 8192];
    let reader = BufReader::with_capacity(1 << 20, file);
    out
}
"#;
    assert!(lint_one("rust/src/rpc/frame.rs", good).is_empty());

    // The rule is scoped: the same bad code outside the decode paths is the
    // responsibility of review, not this rule.
    assert!(lint_one("rust/src/knn/topk.rs", bad).is_empty());
}

#[test]
fn fixture_unsafe_needs_safety_comment() {
    let bad = r#"
fn read(p: *const u8) -> u8 {
    unsafe { *p }
}
"#;
    let findings = lint_one("rust/src/data/fixture.rs", bad);
    assert_eq!(rule_names(&findings), ["unsafe-needs-safety-comment"]);
    assert_eq!(findings[0].line, 3);

    let good = r#"
// SAFETY: callers pass a pointer into the validated, mapped region; the
// header check guarantees it is in bounds and aligned.
fn read(p: *const u8) -> u8 {
    unsafe { *p }
}
"#;
    assert!(lint_one("rust/src/data/fixture.rs", good).is_empty());

    // A SAFETY comment far above the unsafe does not cover it.
    let stale = format!("// SAFETY: stale\n{}fn f(p: *const u8) -> u8 {{ unsafe {{ *p }} }}\n", "\n".repeat(8));
    assert_eq!(rule_names(&lint_one("rust/src/data/fixture.rs", &stale)), ["unsafe-needs-safety-comment"]);
}

#[test]
fn fixture_metric_docs_sync() {
    let registry = r#"
pub const REQUESTS: &str = "opdr_requests_total";
pub const PARTIALS: &str = "opdr_rpc_partial_total";
"#;
    let docs_synced = "//! | `opdr_requests_total` | counter | served requests |\n\
                       //! | `opdr_rpc_partial_total{worker}` | counter | degraded answers |\n";
    let corpus_ok = vec![
        (PathBuf::from("rust/src/telemetry/registry.rs"), registry.to_string()),
        (PathBuf::from("rust/src/coordinator/mod.rs"), docs_synced.to_string()),
    ];
    assert!(lint_sources(&corpus_ok).is_empty());

    // Direction 1: a constant the table does not document.
    let docs_short = "//! | `opdr_requests_total` | counter | served requests |\n";
    let corpus = vec![
        (PathBuf::from("rust/src/telemetry/registry.rs"), registry.to_string()),
        (PathBuf::from("rust/src/coordinator/mod.rs"), docs_short.to_string()),
    ];
    let findings = lint_sources(&corpus);
    assert_eq!(rule_names(&findings), ["metric-docs-sync"]);
    assert!(findings[0].file.ends_with("registry.rs"));
    assert!(findings[0].msg.contains("opdr_rpc_partial_total"));

    // Direction 2: a documented metric with no constant behind it.
    let docs_ghost = "//! | `opdr_requests_total` | counter | served requests |\n\
                      //! | `opdr_rpc_partial_total` | counter | degraded answers |\n\
                      //! | `opdr_ghost_metric` | gauge | removed last PR |\n";
    let corpus = vec![
        (PathBuf::from("rust/src/telemetry/registry.rs"), registry.to_string()),
        (PathBuf::from("rust/src/coordinator/mod.rs"), docs_ghost.to_string()),
    ];
    let findings = lint_sources(&corpus);
    assert_eq!(rule_names(&findings), ["metric-docs-sync"]);
    assert!(findings[0].file.ends_with("coordinator/mod.rs"));
    assert_eq!(findings[0].line, 3);
    assert!(findings[0].msg.contains("opdr_ghost_metric"));
}

#[test]
fn fixture_config_docs_sync() {
    let synced = r#"//! Fixture schema.
//!
//! Keys of the `[serve]` table:
//!
//! | key | type | meaning |
//! |-----|------|---------|
//! | `workers` | int | pool size |
//!
//! Keys of the `[dist]` table:
//!
//! | key | type | meaning |
//! |-----|------|---------|
//! | `workers` | int | shard workers |

fn parse(root: &Value) -> Config {
    let mut cfg = Config::default();
    if let Some(t) = root.get_path("serve") {
        for (key, val) in t {
            match key.as_str() {
                "workers" => cfg.workers = pos_int(val)?,
                other => return err(other),
            }
        }
    }
    if let Some(t) = root.get_path("dist") {
        for (key, val) in t {
            match key.as_str() {
                "workers" => cfg.dist_workers = pos_int(val)?,
                other => return err(other),
            }
        }
    }
    cfg
}
"#;
    assert!(lint_one("rust/src/config/schema.rs", synced).is_empty());

    // An accepted key missing from the docs table fires at the match arm …
    let undocumented = synced.replace(
        "\"workers\" => cfg.dist_workers = pos_int(val)?,",
        "\"workers\" => cfg.dist_workers = pos_int(val)?,\n                \"listen\" => cfg.listen = val.to_string(),",
    );
    let findings = lint_one("rust/src/config/schema.rs", &undocumented);
    assert_eq!(rule_names(&findings), ["config-docs-sync"]);
    assert!(findings[0].msg.contains("`listen`"));
    assert!(findings[0].msg.contains("[dist]"));

    // … and a documented key the parser rejects fires at the table row.
    let ghost = synced.replace(
        "//! | `workers` | int | shard workers |",
        "//! | `workers` | int | shard workers |\n//! | `ghost` | int | removed |",
    );
    let findings = lint_one("rust/src/config/schema.rs", &ghost);
    assert_eq!(rule_names(&findings), ["config-docs-sync"]);
    assert!(findings[0].msg.contains("`ghost`"));

    // Sections are independent: a [serve] row never documents a [dist] key.
    // (The fixture's two `workers` arms prove the converse already.)
    let value_arms_only = synced.replace(
        "\"workers\" => cfg.workers = pos_int(val)?,",
        "\"workers\" => cfg.workers = match val.as_str() { \"ram\" => 1, \"mmap\" => 2, _ => 0 },",
    );
    assert!(lint_one("rust/src/config/schema.rs", &value_arms_only).is_empty());
}

#[test]
fn fixture_no_blanket_allow() {
    let bad = "#![allow(dead_code)]\nfn f() {}\n";
    assert_eq!(rule_names(&lint_one("rust/src/lib.rs", bad)), ["no-blanket-allow"]);

    let bad_item = "#[allow(clippy::all)]\nfn f() {}\n";
    assert_eq!(rule_names(&lint_one("rust/src/x.rs", bad_item)), ["no-blanket-allow"]);

    let bad_warnings = "#[allow(warnings)]\nfn f() {}\n";
    assert_eq!(rule_names(&lint_one("rust/src/x.rs", bad_warnings)), ["no-blanket-allow"]);

    // The retired class: every tracked `too_many_arguments` allow was
    // removed via params-struct refactors, and new ones are rejected.
    let retired = "#[allow(clippy::too_many_arguments)]\nfn f(a: u8, b: u8, c: u8) {}\n";
    assert_eq!(rule_names(&lint_one("rust/src/x.rs", retired)), ["no-blanket-allow"]);

    // Narrow, item-scoped allows of other lints stay allowed.
    let scoped = "#[allow(clippy::needless_range_loop)]\nfn f() {}\n";
    assert!(lint_one("rust/src/x.rs", scoped).is_empty());
}

// ---------------------------------------------------------------------------
// concurrency pass (`opdr-lint analyze`): the live tree must be clean too
// ---------------------------------------------------------------------------

/// Run the concurrency pass over a synthetic corpus of (path, source) pairs.
fn analyze(files: &[(&str, &str)]) -> Vec<Finding> {
    let corpus: Vec<(PathBuf, String)> =
        files.iter().map(|(p, s)| (PathBuf::from(p), s.to_string())).collect();
    opdr_lint::analyze_sources(&corpus)
}

#[test]
fn live_tree_passes_analyze() {
    // Same scope as the CLI's `opdr-lint analyze`: `src` only — integration
    // tests exercise deliberate inversions at runtime (sync_sentinel_it.rs)
    // and must not have to satisfy the static pass to do so.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let findings = opdr_lint::analyze_paths(&[root.join("src")]).expect("walking the live tree");
    assert!(
        findings.is_empty(),
        "opdr-lint analyze must pass clean on the tree; violations:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

#[test]
fn fixture_lock_order() {
    // Two functions taking the same pair of plain locks in opposite orders:
    // a textbook AB/BA deadlock, reported once with the full cycle path.
    let bad = r#"
fn fwd(s: &S) {
    let a = crate::util::lock_recover(&s.alpha);
    let b = crate::util::lock_recover(&s.beta);
    b.push(*a);
}
fn rev(s: &S) {
    let b = crate::util::lock_recover(&s.beta);
    let a = crate::util::lock_recover(&s.alpha);
    a.push(*b);
}
"#;
    let findings = analyze(&[("rust/src/coordinator/fx.rs", bad)]);
    assert_eq!(rule_names(&findings), ["lock-order"]);
    assert!(
        findings[0].msg.contains("fx.alpha -> fx.beta -> fx.alpha"),
        "cycle path missing from: {}",
        findings[0].msg
    );

    // Same order in both functions: a consistent discipline, no finding.
    let good = bad.replace(
        "    let b = crate::util::lock_recover(&s.beta);\n    let a = crate::util::lock_recover(&s.alpha);",
        "    let a = crate::util::lock_recover(&s.alpha);\n    let b = crate::util::lock_recover(&s.beta);",
    );
    assert!(analyze(&[("rust/src/coordinator/fx.rs", &good)]).is_empty());

    // Guard lifetimes are brace-scoped: if `fwd` drops alpha before taking
    // beta, the locks are never held together and no edge exists.
    let scoped = bad.replace(
        "    let a = crate::util::lock_recover(&s.alpha);\n    let b = crate::util::lock_recover(&s.beta);\n    b.push(*a);",
        "    { let a = crate::util::lock_recover(&s.alpha); a.poke(); }\n    let b = crate::util::lock_recover(&s.beta);\n    b.poke();",
    );
    assert!(analyze(&[("rust/src/coordinator/fx.rs", &scoped)]).is_empty());

    // An explicit `drop(guard)` releases early, same effect.
    let dropped = bad.replace(
        "    let a = crate::util::lock_recover(&s.alpha);\n    let b = crate::util::lock_recover(&s.beta);\n    b.push(*a);",
        "    let a = crate::util::lock_recover(&s.alpha);\n    drop(a);\n    let b = crate::util::lock_recover(&s.beta);\n    b.poke();",
    );
    assert!(analyze(&[("rust/src/coordinator/fx.rs", &dropped)]).is_empty());

    // The graph is interprocedural: holding alpha across a call into a
    // function that takes beta is the same edge as taking both inline.
    let via_call = r#"
fn outer(s: &S) {
    let a = crate::util::lock_recover(&s.alpha);
    helper(s);
    a.poke();
}
fn helper(s: &S) {
    let b = crate::util::lock_recover(&s.beta);
    b.poke();
}
fn rev(s: &S) {
    let b = crate::util::lock_recover(&s.beta);
    let a = crate::util::lock_recover(&s.alpha);
    a.push(*b);
}
"#;
    let findings = analyze(&[("rust/src/coordinator/fx.rs", via_call)]);
    assert_eq!(rule_names(&findings), ["lock-order"]);

    // ... and cross-file: the rank table gives ranked sites global names,
    // so the two halves of an inversion in different modules still close
    // the loop. Each half alone is clean; together they cycle, and the
    // downhill half additionally violates the table's order.
    let table = "pub const ALPHA: LockRank = LockRank::new(\"fx.alpha\", 10);\npub const BETA: LockRank = LockRank::new(\"fx.beta\", 20);\n";
    let fwd_file = "fn fwd(s: &S) {\n    let a = lock_recover_ranked(&s.alpha, ranks::ALPHA);\n    let b = lock_recover_ranked(&s.beta, ranks::BETA);\n    b.push(*a);\n}\n";
    let rev_file = "fn rev(s: &S) {\n    let b = lock_recover_ranked(&s.beta, ranks::BETA);\n    let a = lock_recover_ranked(&s.alpha, ranks::ALPHA);\n    a.push(*b);\n}\n";
    assert!(analyze(&[("rust/src/util/sync.rs", table), ("rust/src/coordinator/one.rs", fwd_file)])
        .is_empty());
    let findings = analyze(&[
        ("rust/src/util/sync.rs", table),
        ("rust/src/coordinator/one.rs", fwd_file),
        ("rust/src/coordinator/two.rs", rev_file),
    ]);
    let names = rule_names(&findings);
    assert!(names.contains(&"lock-order"), "cross-file cycle missed: {names:?}");
    assert!(names.contains(&"rank-table-sync"), "downhill edge missed: {names:?}");

    // Bodies under `mod tests` are exempt: deliberate inversions live there
    // and are exercised by the runtime sentinel.
    let in_tests = format!("mod tests {{\n{bad}\n}}\n");
    assert!(analyze(&[("rust/src/coordinator/fx.rs", &in_tests)]).is_empty());
}

#[test]
fn fixture_atomic_ordering() {
    let bad = r#"
fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}
"#;
    let findings = analyze(&[("rust/src/telemetry/fx.rs", bad)]);
    assert_eq!(rule_names(&findings), ["atomic-ordering"]);
    assert_eq!(findings[0].line, 3);

    let good = r#"
fn bump(c: &AtomicU64) {
    // ORDERING: monotonic stat counter; no other memory is published by
    // this add, so Relaxed cannot reorder anything that matters.
    c.fetch_add(1, Ordering::Relaxed);
}
"#;
    assert!(analyze(&[("rust/src/telemetry/fx.rs", good)]).is_empty());

    // The justification must be close: a comment 8 lines up has drifted.
    let stale = format!(
        "// ORDERING: stale\n{}fn f(c: &AtomicU64) {{ c.fetch_add(1, Ordering::Relaxed); }}\n",
        "\n".repeat(8)
    );
    assert_eq!(rule_names(&analyze(&[("rust/src/telemetry/fx.rs", &stale)])), ["atomic-ordering"]);

    // Non-Relaxed orderings carry their own semantics and need no comment.
    let acq = "fn f(c: &AtomicU64) -> u64 { c.load(Ordering::Acquire) }\n";
    assert!(analyze(&[("rust/src/telemetry/fx.rs", acq)]).is_empty());
}

#[test]
fn fixture_rank_table_sync() {
    let table = r#"
pub const ALPHA: LockRank = LockRank::new("fx.alpha", 10);
pub const BETA: LockRank = LockRank::new("fx.beta", 20);
"#;
    let user = r#"
fn f(s: &S) {
    let a = lock_recover_ranked(&s.alpha, ranks::ALPHA);
    let b = lock_recover_ranked(&s.beta, ranks::BETA);
    b.push(*a);
}
"#;
    // Table and call sites agree, acquisition order is rank-increasing.
    assert!(analyze(&[("rust/src/util/sync.rs", table), ("rust/src/coordinator/fx.rs", user)])
        .is_empty());

    // Direction 1: a declared constant no call site uses.
    let wide = format!("{table}pub const GAMMA: LockRank = LockRank::new(\"fx.gamma\", 30);\n");
    let findings = analyze(&[("rust/src/util/sync.rs", &wide), ("rust/src/coordinator/fx.rs", user)]);
    assert_eq!(rule_names(&findings), ["rank-table-sync"]);
    assert!(findings[0].file.ends_with("util/sync.rs"));
    assert!(findings[0].msg.contains("GAMMA"));

    // Direction 2: a call site naming a constant the table lacks — which
    // also leaves the real `BETA` constant unused, so both directions fire.
    let ghost = user.replace("ranks::BETA", "ranks::DELTA");
    let findings = analyze(&[("rust/src/util/sync.rs", table), ("rust/src/coordinator/fx.rs", &ghost)]);
    assert_eq!(rule_names(&findings), ["rank-table-sync"; 2]);
    assert!(findings[0].file.ends_with("coordinator/fx.rs"));
    assert!(findings[0].msg.contains("DELTA"));
    assert!(findings[1].file.ends_with("util/sync.rs"));
    assert!(findings[1].msg.contains("BETA"));

    // Direction 3: an edge that runs against the table's order — exactly
    // what the runtime sentinel would panic on, caught at lint time.
    let inverted = r#"
fn f(s: &S) {
    let b = lock_recover_ranked(&s.beta, ranks::BETA);
    let a = lock_recover_ranked(&s.alpha, ranks::ALPHA);
    a.push(*b);
}
"#;
    let findings =
        analyze(&[("rust/src/util/sync.rs", table), ("rust/src/coordinator/fx.rs", inverted)]);
    assert_eq!(rule_names(&findings), ["rank-table-sync"]);
    assert!(findings[0].msg.contains("strictly increasing"), "got: {}", findings[0].msg);

    // The table itself must be a total order: duplicate ranks and names fire.
    let dup_rank = r#"
pub const ALPHA: LockRank = LockRank::new("fx.alpha", 10);
pub const BETA: LockRank = LockRank::new("fx.beta", 10);
"#;
    let findings = analyze(&[("rust/src/util/sync.rs", dup_rank)]);
    assert!(findings.iter().any(|f| f.msg.contains("ranks must be unique")));

    let dup_name = r#"
pub const ALPHA: LockRank = LockRank::new("fx.alpha", 10);
pub const ALPHA2: LockRank = LockRank::new("fx.alpha", 20);
"#;
    let findings = analyze(&[("rust/src/util/sync.rs", dup_name)]);
    assert!(findings.iter().any(|f| f.msg.contains("duplicate site name")));
}

#[test]
fn fixture_unbounded_channel() {
    // On a serving/build path, an unbounded channel is a backpressure bug.
    let bad = "fn f() {\n    let (tx, rx) = std::sync::mpsc::channel();\n    tx.send(1).ok();\n}\n";
    let findings = analyze(&[("rust/src/pool.rs", bad)]);
    assert_eq!(rule_names(&findings), ["unbounded-channel"]);
    assert_eq!(findings[0].line, 2);

    // The turbofish form is the same call.
    let turbo = "fn f() { let (tx, rx) = channel::<u64>(); }\n";
    assert_eq!(rule_names(&analyze(&[("rust/src/index/shard.rs", turbo)])), ["unbounded-channel"]);

    // Bounded channels are the fix, not a violation.
    let good = "fn f() { let (tx, rx) = std::sync::mpsc::sync_channel(8); }\n";
    assert!(analyze(&[("rust/src/pool.rs", good)]).is_empty());

    // The rule is scoped to the serving/build paths, like bounded-prealloc.
    let elsewhere = "fn f() { let (tx, rx) = std::sync::mpsc::channel(); }\n";
    assert!(analyze(&[("rust/src/knn/topk.rs", elsewhere)]).is_empty());

    // The escape hatch reaches analyze rules too.
    let allowed = "fn f() {\n    // lint:allow(unbounded-channel: fixture)\n    let (tx, rx) = std::sync::mpsc::channel();\n}\n";
    assert!(analyze(&[("rust/src/pool.rs", allowed)]).is_empty());
}

// ---------------------------------------------------------------------------
// escape hatch
// ---------------------------------------------------------------------------

#[test]
fn escape_hatch_lint_allow() {
    // Same line, with a reason.
    let same = "fn f(m: &Mutex<u8>) { let _ = m.lock().unwrap(); } // lint:allow(no-naked-lock-unwrap: fixture)\n";
    assert!(lint_one("rust/src/x.rs", same).is_empty());

    // Line above, bare form.
    let above = "// lint:allow(no-naked-lock-unwrap)\nfn f(m: &Mutex<u8>) { let _ = m.lock().unwrap(); }\n";
    assert!(lint_one("rust/src/x.rs", above).is_empty());

    // The allow names a rule, not a site: another rule still fires there.
    let wrong = "// lint:allow(bounded-prealloc: wrong rule)\nfn f(m: &Mutex<u8>) { let _ = m.lock().unwrap(); }\n";
    assert_eq!(rule_names(&lint_one("rust/src/x.rs", wrong)), ["no-naked-lock-unwrap"]);

    // Reach is bounded: an allow three lines up no longer covers.
    let far = "// lint:allow(no-naked-lock-unwrap)\n\n\nfn f(m: &Mutex<u8>) { let _ = m.lock().unwrap(); }\n";
    assert_eq!(rule_names(&lint_one("rust/src/x.rs", far)), ["no-naked-lock-unwrap"]);

    // An allow hidden inside a string literal is not an annotation.
    let quoted = "const S: &str = \"lint:allow(no-naked-lock-unwrap)\";\nfn f(m: &Mutex<u8>) { let _ = m.lock().unwrap(); }\n";
    assert_eq!(rule_names(&lint_one("rust/src/x.rs", quoted)), ["no-naked-lock-unwrap"]);
}

// ---------------------------------------------------------------------------
// diagnostics shape
// ---------------------------------------------------------------------------

#[test]
fn diagnostics_carry_file_line_and_rule() {
    let findings = lint_one("rust/src/coordinator/fx.rs", "fn f(m: &Mutex<u8>) { m.lock().unwrap(); }\n");
    assert_eq!(findings.len(), 1);
    let shown = findings[0].to_string();
    assert!(
        shown.starts_with("rust/src/coordinator/fx.rs:1: [no-naked-lock-unwrap]"),
        "diagnostic format regressed: {shown}"
    );
}

#[test]
fn every_rule_is_catalogued() {
    // The rule list is the contract between this matrix, the CI guard, and
    // the README catalogue; a rule must not exist without a summary.
    let names: Vec<&str> = opdr_lint::RULES.iter().map(|(n, _)| *n).collect();
    for expected in [
        "no-partial-cmp-ordering",
        "no-naked-lock-unwrap",
        "bounded-prealloc",
        "unsafe-needs-safety-comment",
        "metric-docs-sync",
        "config-docs-sync",
        "no-blanket-allow",
    ] {
        assert!(names.contains(&expected), "rule {expected} missing from RULES");
    }
    assert!(opdr_lint::RULES.iter().all(|(_, s)| !s.is_empty()));

    let analyze_names: Vec<&str> = opdr_lint::ANALYZE_RULES.iter().map(|(n, _)| *n).collect();
    for expected in ["lock-order", "atomic-ordering", "rank-table-sync", "unbounded-channel"] {
        assert!(
            analyze_names.contains(&expected),
            "rule {expected} missing from ANALYZE_RULES"
        );
    }
    assert!(opdr_lint::ANALYZE_RULES.iter().all(|(_, s)| !s.is_empty()));
}
