//! Cross-version `OPDR` store compatibility matrix.
//!
//! Fixture-driven: one representative file per store version (v1 embedding
//! set, v2 single-segment index, v3 sharded index, v4 delta-augmented
//! index, v5 cold-tier index) is written, then every fixture is asserted to
//! (a) load through the public entry points, (b) fail with the right typed
//! error when truncated at several cuts, and (c) fail when a trailing byte
//! is appended — at *every* version. The v5 fixture additionally proves the
//! written-once / loaded-twice contract: the heap-loaded and mmap-loaded
//! indexes search bitwise identically.

use opdr::config::IndexPolicy;
use opdr::data::{store, synth, DatasetKind, EmbeddingSet};
use opdr::index::{AnnIndex, DeltaIndex, IndexKind};
use opdr::metrics::Metric;
use std::path::PathBuf;
use std::sync::Arc;

const DIM: usize = 8;
const N: usize = 64;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("opdr_store_compat_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fixture_set() -> EmbeddingSet {
    synth::generate(DatasetKind::Flickr30k, N, DIM, 19)
}

fn build(policy: &IndexPolicy, rows: usize, set: &EmbeddingSet) -> Box<dyn AnnIndex> {
    opdr::index::build_index(&set.data()[..rows * DIM], DIM, Metric::SqEuclidean, policy, 11)
        .unwrap()
}

/// One fixture per store version: `(version, file bytes)`.
fn version_fixtures(set: &EmbeddingSet) -> Vec<(u32, Vec<u8>)> {
    let exact = IndexPolicy {
        kind: IndexKind::Exact,
        exact_threshold: 0,
        pq: true,
        rerank_depth: N,
        ..Default::default()
    };
    let sharded = IndexPolicy { shards: 3, shard_min_vectors: 1, ..exact.clone() };

    let mut out = Vec::new();
    let mut v1 = Vec::new();
    store::write_embeddings(set, &mut v1).unwrap();
    out.push((1, v1));

    let idx2 = build(&exact, N, set);
    let mut v2 = Vec::new();
    store::write_index(idx2.as_ref(), &mut v2).unwrap();
    out.push((2, v2));

    let idx3 = build(&sharded, N, set);
    let mut v3 = Vec::new();
    store::write_index(idx3.as_ref(), &mut v3).unwrap();
    out.push((3, v3));

    let main = build(&exact, N - 10, set);
    let idx4 =
        DeltaIndex::from_parts(Arc::from(main), set.data()[(N - 10) * DIM..].to_vec()).unwrap();
    let mut v4 = Vec::new();
    store::write_index(&idx4, &mut v4).unwrap();
    out.push((4, v4));

    let idx5 = build(&sharded, N, set);
    let mut v5 = Vec::new();
    store::write_index_cold(idx5.as_ref(), &mut v5).unwrap();
    out.push((5, v5));

    out
}

#[test]
fn every_version_loads_and_declares_its_version() {
    let dir = tmp_dir("load");
    let set = fixture_set();
    for (version, bytes) in version_fixtures(&set) {
        assert_eq!(
            u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
            version,
            "fixture v{version} mislabeled"
        );
        let path = dir.join(format!("fixture-v{version}.opdr"));
        std::fs::write(&path, &bytes).unwrap();
        if version == 1 {
            let back = store::load(&path).unwrap();
            assert_eq!(back, set, "v1 embedding set must round-trip");
            continue;
        }
        let back = store::load_index(&path).unwrap();
        assert_eq!(back.len(), N, "v{version} index loads all rows");
        assert!(back.matches_data(set.data()), "v{version} rows survive bitwise");
        // A stored row's own query self-hits through every version.
        let hits = back.search(set.vector(5), 3).unwrap();
        assert_eq!(hits[0].index, 5, "v{version} self-hit");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncation_and_trailing_bytes_rejected_at_every_version() {
    let dir = tmp_dir("corrupt");
    let set = fixture_set();
    for (version, bytes) in version_fixtures(&set) {
        let load = |raw: &[u8], what: &str| -> String {
            let path = dir.join(format!("corrupt-v{version}.opdr"));
            std::fs::write(&path, raw).unwrap();
            let res = if version == 1 {
                store::load(&path).map(|_| ()).map_err(|e| e.to_string())
            } else {
                store::load_index(&path).map(|_| ()).map_err(|e| e.to_string())
            };
            res.expect_err(&format!("v{version}: {what} accepted"))
        };
        // Truncation at several cuts: inside the header, mid-payload, and
        // just short of the end — every cut must fail with a typed error
        // (exercised through Display), never panic or misparse.
        for cut in [6usize, bytes.len() / 3, bytes.len() / 2, bytes.len() - 2] {
            let msg = load(&bytes[..cut], &format!("truncation at {cut}"));
            assert!(msg.contains("error"), "v{version}: untyped failure: {msg}");
        }
        // A single trailing byte after a valid payload must be rejected,
        // not silently ignored (count-mismatch corruption).
        let mut more = bytes.clone();
        more.push(0x5A);
        let msg = load(&more, "trailing byte");
        assert!(
            msg.contains("trailing") || msg.contains("header declares"),
            "v{version}: {msg}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v5_heap_and_mmap_loads_are_bitwise_equal() {
    // Acceptance criterion: a v5-written file loaded through the heap path
    // is bitwise equal to the mmap-loaded index — same neighbors, same
    // distance bits, for a spread of queries and k.
    let dir = tmp_dir("v5");
    let set = fixture_set();
    let policy = IndexPolicy {
        kind: IndexKind::Exact,
        exact_threshold: 0,
        pq: true,
        rerank_depth: N,
        shards: 3,
        shard_min_vectors: 1,
        ..Default::default()
    };
    let idx = build(&policy, N, &set);
    let path = dir.join("tier.opdx");
    store::save_index_cold(idx.as_ref(), &path).unwrap();
    let mapped = store::load_index(&path).unwrap();
    let heap = store::load_index_heap(&path).unwrap();
    assert_eq!(heap.mapped_bytes(), 0, "forced heap load must map nothing");
    for qi in [0usize, 13, 37, N - 1] {
        for k in [1usize, 7, N + 3] {
            let a = idx.search(set.vector(qi), k).unwrap();
            let b = mapped.search(set.vector(qi), k).unwrap();
            let c = heap.search(set.vector(qi), k).unwrap();
            opdr::testing::assert_same_neighbors(&a, &b);
            opdr::testing::assert_same_neighbors(&a, &c);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
