//! Integration tests over the PJRT runtime + real AOT artifacts.
//!
//! These require `make artifacts` (the Makefile test target guarantees it);
//! when artifacts are missing the tests skip with a note instead of failing,
//! so plain `cargo test` works on a fresh checkout.

use opdr::data::records::{generate_records, TEXT_FEAT, TEXT_TOKENS};
use opdr::data::DatasetKind;
use opdr::embed::{embed_records, Encoder, ModelKind, RuntimeEncoder};
use opdr::metrics::Metric;
use opdr::runtime::{ArrayF32, Engine};
use opdr::util::Rng;

fn engine_or_skip() -> Option<Engine> {
    match Engine::new("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP (run `make artifacts`): {err}");
            None
        }
    }
}

#[test]
fn pairwise_topk_artifact_matches_rust_reference() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Rng::new(1);
    let (q_cap, n_cap, d_cap, k_cap) = (32usize, 1024usize, 1024usize, 64usize);
    let live_n = 300;
    let live_d = 192;
    let live_q = 8;
    let queries = rng.normal_vec_f32(live_q * live_d);
    let base = rng.normal_vec_f32(live_n * live_d);

    let q_in = ArrayF32::padded_2d(&queries, live_q, live_d, q_cap, d_cap).unwrap();
    let b_in = ArrayF32::padded_2d(&base, live_n, live_d, n_cap, d_cap).unwrap();
    let mut mask = vec![0.0f32; n_cap];
    for m in mask.iter_mut().skip(live_n) {
        *m = 1.0;
    }
    let mask_in = ArrayF32::new(mask, vec![n_cap]).unwrap();

    for metric in [Metric::SqEuclidean, Metric::Cosine, Metric::Manhattan] {
        let artifact = format!("pairwise_topk_{}", metric.name());
        let out = engine
            .execute(&artifact, &[q_in.clone(), b_in.clone(), mask_in.clone()])
            .unwrap();
        let dists = &out[0];
        let idxs = &out[1];
        assert_eq!(dists.shape, vec![q_cap, k_cap]);

        // Compare against exact rust KNN for each live query.
        for qi in 0..live_q {
            let exact = opdr::knn::knn_indices(
                &queries[qi * live_d..(qi + 1) * live_d],
                &base,
                live_d,
                10,
                metric,
            )
            .unwrap();
            for (j, nb) in exact.iter().enumerate() {
                let got_idx = idxs.data[qi * k_cap + j] as usize;
                let got_dist = dists.data[qi * k_cap + j];
                assert_eq!(got_idx, nb.index, "{artifact} q{qi} rank {j}");
                assert!(
                    (got_dist - nb.distance).abs() < 1e-2 * (1.0 + nb.distance.abs()),
                    "{artifact} q{qi} rank {j}: {got_dist} vs {}",
                    nb.distance
                );
            }
        }
    }
}

#[test]
fn pca_project_artifact_matches_rust_projection() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Rng::new(2);
    let (b_cap, d_cap) = (64usize, 1024usize);
    let live_b = 10;
    let live_d = 128;
    let target = 16;

    // Fit a PCA on random data in rust, project via artifact, compare.
    let data = rng.normal_vec_f32(40 * live_d);
    let model = opdr::reduction::Pca::new().fit(&data, live_d, target).unwrap();
    let queries = rng.normal_vec_f32(live_b * live_d);
    let want = model.project(&queries).unwrap();

    // Build padded inputs: x must be CENTERED before the artifact (the HLO
    // graph is a plain projection; mean subtraction is the caller's job).
    let means = model.means();
    let mut centered = queries.clone();
    for r in 0..live_b {
        for j in 0..live_d {
            centered[r * live_d + j] -= means[j] as f32;
        }
    }
    let x_in = ArrayF32::padded_2d(&centered, live_b, live_d, b_cap, d_cap).unwrap();
    let comp = model.components_f32(); // live_d × target
    let w_in = ArrayF32::padded_2d(&comp, live_d, target, d_cap, d_cap).unwrap();

    let out = engine.execute("pca_project", &[x_in, w_in]).unwrap();
    let got = &out[0];
    for r in 0..live_b {
        for c in 0..target {
            let g = got.data[r * d_cap + c];
            let w = want[r * target + c];
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "({r},{c}): {g} vs {w}");
        }
        // Padding columns must be exactly zero (zero-padded components).
        for c in target..(target + 8) {
            assert_eq!(got.data[r * d_cap + c], 0.0);
        }
    }
}

#[test]
fn covariance_artifact_matches_rust_covariance() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Rng::new(3);
    let (m_cap, d_cap) = (128usize, 512usize);
    // Use the full fixed shape (padding rows would shift the column means in
    // the graph's centering; full-shape usage is the supported contract).
    let data = rng.normal_vec_f32(m_cap * d_cap);
    let x_in = ArrayF32::new(data.clone(), vec![m_cap, d_cap]).unwrap();
    let out = engine.execute("covariance", &[x_in]).unwrap();
    let got = &out[0];
    assert_eq!(got.shape, vec![d_cap, d_cap]);

    let x = opdr::linalg::Mat::from_f32(m_cap, d_cap, &data).unwrap();
    let mut want = opdr::linalg::covariance_matrix(&x).unwrap();
    want.scale(m_cap as f64 - 1.0); // artifact returns raw centered Gram
    for idx in (0..d_cap * d_cap).step_by(9173) {
        let (i, j) = (idx / d_cap, idx % d_cap);
        let g = got.data[idx] as f64;
        let w = want[(i, j)];
        assert!((g - w).abs() < 1e-2 * (1.0 + w.abs()), "({i},{j}): {g} vs {w}");
    }
}

#[test]
fn encoder_towers_execute_and_are_deterministic() {
    let Some(engine) = engine_or_skip() else { return };
    let enc = RuntimeEncoder::new(&engine);
    let recs = generate_records(DatasetKind::Esc50, 5, 7);

    for model in [ModelKind::Clip, ModelKind::Bert, ModelKind::Vit, ModelKind::BertPanns] {
        let a = embed_records(&enc, model, &recs, "it").unwrap();
        let b = embed_records(&enc, model, &recs, "it").unwrap();
        assert_eq!(a.len(), 5);
        assert_eq!(a.dim(), model.output_dim());
        assert_eq!(a.data(), b.data(), "{} not deterministic", model.name());
        assert!(a.data().iter().all(|x| x.is_finite()));
    }
}

#[test]
fn runtime_embeddings_cluster_by_class() {
    // The substitution argument (DESIGN.md §1) requires encoder outputs to
    // inherit record cluster structure; verify on the real towers.
    let Some(engine) = engine_or_skip() else { return };
    let enc = RuntimeEncoder::new(&engine);
    let recs = generate_records(DatasetKind::MaterialsObservable, 24, 11);
    let set = embed_records(&enc, ModelKind::Clip, &recs, "it").unwrap();
    let dim = set.dim();
    let mut same = Vec::new();
    let mut diff = Vec::new();
    for i in 0..set.len() {
        for j in (i + 1)..set.len() {
            let d = opdr::metrics::sq_euclidean(set.vector(i), set.vector(j)) as f64;
            if recs[i].class == recs[j].class {
                same.push(d);
            } else {
                diff.push(d);
            }
        }
    }
    assert!(!same.is_empty() && !diff.is_empty());
    let ms = opdr::util::float::mean(&same);
    let md = opdr::util::float::mean(&diff);
    assert!(ms < md, "same-class {ms} !< cross-class {md} (dim {dim})");
}

#[test]
fn encode_batch_rejects_oversized_batches() {
    let Some(engine) = engine_or_skip() else { return };
    let enc = RuntimeEncoder::new(&engine);
    let recs = generate_records(DatasetKind::Flickr30k, 9, 1); // > ENCODER_BATCH
    assert!(enc.encode_batch(ModelKind::Bert, &recs).is_err());
    // And record feature-size mismatches.
    let mut bad = generate_records(DatasetKind::Flickr30k, 1, 1);
    bad[0].text.truncate(TEXT_TOKENS * TEXT_FEAT - 1);
    assert!(enc.encode_batch(ModelKind::Bert, &bad).is_err());
}

#[test]
fn engine_validates_shapes_against_manifest() {
    let Some(engine) = engine_or_skip() else { return };
    // Wrong arity.
    assert!(engine.execute("pca_project", &[]).is_err());
    // Wrong shape.
    let bad = ArrayF32::zeros(&[1, 1]);
    assert!(engine.execute("pca_project", &[bad.clone(), bad]).is_err());
    // Unknown artifact.
    assert!(engine.execute("nope", &[]).is_err());
}
