//! Failure-injection tests: the system must fail loudly and recoverably,
//! never silently serve garbage.

use opdr::config::ServeConfig;
use opdr::coordinator::Coordinator;
use opdr::data::{synth, DatasetKind};
use opdr::metrics::Metric;
use opdr::runtime::Engine;
use std::io::Write;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("opdr_fail_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = tmpdir("manifest");
    std::fs::write(dir.join("manifest.toml"), "this is { not toml").unwrap();
    let err = Engine::new(&dir).unwrap_err().to_string();
    assert!(err.contains("config") || err.contains("manifest") || err.contains("line"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_pointing_at_missing_hlo_fails_on_use() {
    let dir = tmpdir("missing_hlo");
    std::fs::write(
        dir.join("manifest.toml"),
        "[artifacts.ghost]\nfile = \"ghost.hlo.txt\"\ninputs = [\"f32:2x2\"]\noutputs = [\"f32:2x2\"]\n",
    )
    .unwrap();
    let engine = Engine::new(&dir).unwrap(); // lazy: construction succeeds
    let err = engine.warmup("ghost").unwrap_err().to_string();
    assert!(!err.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_hlo_text_fails_to_parse_not_to_garbage() {
    let dir = tmpdir("corrupt_hlo");
    let mut f = std::fs::File::create(dir.join("bad.hlo.txt")).unwrap();
    writeln!(f, "HloModule bad\nENTRY main {{ garbage garbage }}").unwrap();
    std::fs::write(
        dir.join("manifest.toml"),
        "[artifacts.bad]\nfile = \"bad.hlo.txt\"\ninputs = [\"f32:2x2\"]\noutputs = [\"f32:2x2\"]\n",
    )
    .unwrap();
    let engine = Engine::new(&dir).unwrap();
    assert!(engine.warmup("bad").is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_output_mismatch_detected_at_execute() {
    // Point the manifest at a REAL artifact but declare wrong output shapes:
    // execute must detect the drift instead of mis-slicing results.
    if !std::path::Path::new("artifacts/pca_project.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let dir = tmpdir("shape_drift");
    std::fs::copy("artifacts/pca_project.hlo.txt", dir.join("p.hlo.txt")).unwrap();
    std::fs::write(
        dir.join("manifest.toml"),
        // true shapes are [64,1024]x[1024,1024] -> [64,1024]
        "[artifacts.p]\nfile = \"p.hlo.txt\"\ninputs = [\"f32:64x1024\", \"f32:1024x1024\"]\noutputs = [\"f32:64x512\"]\n",
    )
    .unwrap();
    let engine = Engine::new(&dir).unwrap();
    let x = opdr::runtime::ArrayF32::zeros(&[64, 1024]);
    let w = opdr::runtime::ArrayF32::zeros(&[1024, 1024]);
    let err = engine.execute("p", &[x, w]).unwrap_err().to_string();
    assert!(err.contains("elems") || err.contains("output"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coordinator_survives_failing_requests_and_keeps_serving() {
    let coord = Coordinator::start(ServeConfig { workers: 2, ..Default::default() }).unwrap();
    coord.create_collection("ok", 8, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Flickr30k, 30, 8, 1);
    coord.ingest("ok", set.data().to_vec()).unwrap();

    // A burst of failures: wrong collection, wrong dims, zero-k.
    for _ in 0..20 {
        assert!(coord.search("nope", vec![0.0; 8], 3).is_err());
        assert!(coord.search("ok", vec![0.0; 5], 3).is_err()); // wrong dim
    }
    // Still healthy.
    let res = coord.search("ok", set.vector(2).to_vec(), 3).unwrap();
    assert_eq!(res.neighbors[0].index, 2);
    coord.shutdown();
}

#[test]
fn wrong_dim_query_rejected_not_mis_scored() {
    let coord = Coordinator::start(ServeConfig::default()).unwrap();
    coord.create_collection("c", 16, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Esc50, 20, 16, 2);
    coord.ingest("c", set.data().to_vec()).unwrap();
    let err = coord.search("c", vec![0.0; 15], 3);
    assert!(err.is_err());
    let err = coord.search("c", vec![0.0; 17], 3);
    assert!(err.is_err());
    coord.shutdown();
}

#[test]
fn store_load_of_truncated_file_errors() {
    let dir = tmpdir("store");
    let set = synth::generate(DatasetKind::Flickr30k, 5, 4, 3);
    let path = dir.join("x.opdr");
    opdr::data::store::save(&set, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(opdr::data::store::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reducer_rejects_degenerate_inputs_cleanly() {
    use opdr::reduction::ReducerKind;
    // All-identical points: PCA/MDS must not panic (zero variance).
    let data = vec![1.0f32; 10 * 6];
    for kind in [ReducerKind::Pca, ReducerKind::ClassicalMds, ReducerKind::Smacof] {
        match kind.build(0).fit_transform(&data, 6, 2) {
            Ok(out) => assert!(out.iter().all(|x| x.is_finite()), "{}", kind.name()),
            Err(_) => {} // clean error is acceptable; panic is not
        }
    }
    // NaN inputs: must error, not propagate silently through eigh.
    let mut nan_data = vec![0.5f32; 8 * 4];
    nan_data[5] = f32::NAN;
    assert!(ReducerKind::Pca.build(0).fit_transform(&nan_data, 4, 2).is_err());
}

// ---------------------------------------------------------------------------
// RPC transport failure injection (distribution layer): same creed — typed
// errors and flagged degraded answers, never a hang or silent garbage.
// ---------------------------------------------------------------------------

fn dist_exact(rows: &[f32], dim: usize) -> std::sync::Arc<dyn opdr::index::AnnIndex> {
    use opdr::index::{ExactIndex, StorageSpec};
    std::sync::Arc::new(
        ExactIndex::build(rows, dim, Metric::SqEuclidean, &StorageSpec::flat(), 7).unwrap(),
    )
}

/// A worker socket that accepts connections and then never says a word: the
/// gateway's per-request deadline must fire (recorded in
/// `opdr_rpc_deadline_total`, not the generic error counter), the answer
/// must arrive promptly from the surviving shard flagged `partial`, and no
/// thread may stay blocked — the second query is just as prompt.
#[test]
fn stalled_rpc_worker_socket_hits_the_deadline_and_is_counted() {
    use opdr::config::DistConfig;
    use opdr::dist::{Gateway, ThreadWorker, WorkerSpec};
    use opdr::index::AnnIndex as _;
    use opdr::telemetry::registry::{RPC_DEADLINE_TOTAL, RPC_PARTIAL_TOTAL};
    use opdr::telemetry::Registry;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let dim = 8;
    let rows = synth::generate(DatasetKind::Flickr30k, 40, dim, 11).data().to_vec();
    let index = dist_exact(&rows, dim);

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let stalled_addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let holder = std::thread::spawn(move || {
        listener.set_nonblocking(true).unwrap();
        let mut held = Vec::new(); // accepted, never answered
        while !stop2.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((s, _)) => held.push(s),
                Err(_) => std::thread::sleep(Duration::from_millis(3)),
            }
        }
    });

    let live = ThreadWorker::spawn(Arc::clone(&index), 0).unwrap();
    let specs = vec![
        WorkerSpec::fixed("stalled", stalled_addr),
        WorkerSpec::fixed("live", live.addr()),
    ];
    let cfg = DistConfig {
        workers: 2,
        listen: "127.0.0.1:0".to_string(),
        connect_timeout_ms: 150,
        request_deadline_ms: 150,
        ..Default::default()
    };
    let registry = Arc::new(Registry::new());
    let mut gw = Gateway::new(specs, cfg, Arc::clone(&registry));

    let q = &rows[..dim];
    let want: Vec<(usize, u32)> =
        index.search(q, 5).unwrap().iter().map(|nb| (nb.index, nb.distance.to_bits())).collect();
    for round in 0..2 {
        let t0 = Instant::now();
        let res = gw.search(q, 5).unwrap();
        let took = t0.elapsed();
        assert!(took < Duration::from_secs(2), "round {round}: stalled socket blocked {took:?}");
        assert!(res.partial, "round {round}: degraded answer must be flagged");
        assert_eq!(res.shards_ok, 1, "round {round}");
        let got: Vec<(usize, u32)> =
            res.neighbors.iter().map(|nb| (nb.index, nb.distance.to_bits())).collect();
        assert_eq!(got, want, "round {round}: surviving shard must serve bitwise");
    }
    assert!(
        registry.counter(RPC_DEADLINE_TOTAL, &[("worker", "stalled")]).get() >= 2,
        "deadline misses must land in opdr_rpc_deadline_total"
    );
    assert!(registry.counter(RPC_PARTIAL_TOTAL, &[]).get() >= 2);
    stop.store(true, Ordering::Relaxed);
    holder.join().unwrap();
}

/// A corrupted request frame must come back as a typed `Error` naming the
/// CRC (or a clean close) — and the worker must drop the desynchronized
/// connection instead of guessing at frame boundaries.
#[test]
fn corrupt_rpc_frame_gets_a_typed_error_then_a_clean_close() {
    use opdr::dist::ThreadWorker;
    use opdr::rpc::{Fault, FaultScript, FaultyTransport, Message, PROTOCOL_VERSION};
    use std::time::{Duration, Instant};

    let dim = 8;
    let rows = synth::generate(DatasetKind::Flickr30k, 20, dim, 12).data().to_vec();
    let worker = ThreadWorker::spawn(dist_exact(&rows, dim), 0).unwrap();

    let stream = std::net::TcpStream::connect(worker.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // Frame 0 (hello) travels clean; frame 1 (search) gets a payload byte
    // flipped in flight.
    let mut t = FaultyTransport::new(stream, FaultScript::fault_at(1, Fault::Corrupt(30)));
    t.send(7, &Message::Hello { version: PROTOCOL_VERSION }).unwrap();
    let (rid, ack) = t.recv().unwrap();
    assert_eq!(rid, 7);
    assert!(matches!(ack, Message::HelloAck { .. }), "got {}", ack.kind_name());

    t.send(8, &Message::Search { k: 3, query: vec![0.25; dim], trace_id: None }).unwrap();
    match t.recv() {
        Ok((_, Message::Error { message })) => {
            assert!(message.contains("crc"), "typed reason expected, got: {message}");
        }
        Ok((_, other)) => panic!("corrupted frame answered with {}", other.kind_name()),
        Err(_) => {} // closing before the best-effort error write is also legal
    }
    // The connection is dead — promptly, not after a hang.
    let t0 = Instant::now();
    let _ = t.send(9, &Message::Ping);
    assert!(t.recv().is_err(), "worker must drop a desynchronized connection");
    assert!(t0.elapsed() < Duration::from_secs(5));
}

/// A frame truncated mid-payload kills that connection only: the client
/// sees a prompt close (no resync guessing), and the worker keeps serving
/// fresh connections bitwise-correctly.
#[test]
fn truncated_rpc_frame_closes_the_connection_not_the_worker() {
    use opdr::dist::ThreadWorker;
    use opdr::index::AnnIndex as _;
    use opdr::rpc::{Fault, FaultScript, FaultyTransport, FramedTcp, Message, PROTOCOL_VERSION};
    use std::time::Duration;

    let dim = 8;
    let rows = synth::generate(DatasetKind::Flickr30k, 20, dim, 13).data().to_vec();
    let index = dist_exact(&rows, dim);
    let worker = ThreadWorker::spawn(std::sync::Arc::clone(&index), 0).unwrap();

    let stream = std::net::TcpStream::connect(worker.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut t = FaultyTransport::new(stream, FaultScript::fault_at(1, Fault::Truncate(30)));
    t.send(1, &Message::Hello { version: PROTOCOL_VERSION }).unwrap();
    assert!(matches!(t.recv().unwrap().1, Message::HelloAck { .. }));
    // Only the first 30 of the search frame's bytes leave; sever the write
    // half so the worker sees EOF mid-frame instead of a stall.
    t.send(2, &Message::Search { k: 3, query: vec![0.5; dim], trace_id: None }).unwrap();
    t.inner().shutdown(std::net::Shutdown::Write).unwrap();
    assert!(t.recv().is_err(), "truncated frame cannot produce a reply");

    // The worker itself is unharmed: a fresh connection serves bitwise.
    let stream = std::net::TcpStream::connect(worker.addr()).unwrap();
    let mut conn = FramedTcp::new(stream);
    conn.set_deadline(Duration::from_secs(5)).unwrap();
    conn.send(1, &Message::Hello { version: PROTOCOL_VERSION }).unwrap();
    assert!(matches!(conn.recv().unwrap().1, Message::HelloAck { .. }));
    let q = &rows[..dim];
    conn.send(2, &Message::Search { k: 3, query: q.to_vec(), trace_id: None }).unwrap();
    match conn.recv().unwrap() {
        (2, Message::SearchOk { neighbors, .. }) => {
            let want: Vec<(u64, u32)> = index
                .search(q, 3)
                .unwrap()
                .iter()
                .map(|nb| (nb.index as u64, nb.distance.to_bits()))
                .collect();
            let got: Vec<(u64, u32)> =
                neighbors.iter().map(|&(id, d)| (id, d.to_bits())).collect();
            assert_eq!(got, want);
        }
        (rid, other) => panic!("expected search-ok rid 2, got {} rid {rid}", other.kind_name()),
    }
}
