//! Failure-injection tests: the system must fail loudly and recoverably,
//! never silently serve garbage.

use opdr::config::ServeConfig;
use opdr::coordinator::Coordinator;
use opdr::data::{synth, DatasetKind};
use opdr::metrics::Metric;
use opdr::runtime::Engine;
use std::io::Write;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("opdr_fail_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = tmpdir("manifest");
    std::fs::write(dir.join("manifest.toml"), "this is { not toml").unwrap();
    let err = Engine::new(&dir).unwrap_err().to_string();
    assert!(err.contains("config") || err.contains("manifest") || err.contains("line"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_pointing_at_missing_hlo_fails_on_use() {
    let dir = tmpdir("missing_hlo");
    std::fs::write(
        dir.join("manifest.toml"),
        "[artifacts.ghost]\nfile = \"ghost.hlo.txt\"\ninputs = [\"f32:2x2\"]\noutputs = [\"f32:2x2\"]\n",
    )
    .unwrap();
    let engine = Engine::new(&dir).unwrap(); // lazy: construction succeeds
    let err = engine.warmup("ghost").unwrap_err().to_string();
    assert!(!err.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_hlo_text_fails_to_parse_not_to_garbage() {
    let dir = tmpdir("corrupt_hlo");
    let mut f = std::fs::File::create(dir.join("bad.hlo.txt")).unwrap();
    writeln!(f, "HloModule bad\nENTRY main {{ garbage garbage }}").unwrap();
    std::fs::write(
        dir.join("manifest.toml"),
        "[artifacts.bad]\nfile = \"bad.hlo.txt\"\ninputs = [\"f32:2x2\"]\noutputs = [\"f32:2x2\"]\n",
    )
    .unwrap();
    let engine = Engine::new(&dir).unwrap();
    assert!(engine.warmup("bad").is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_output_mismatch_detected_at_execute() {
    // Point the manifest at a REAL artifact but declare wrong output shapes:
    // execute must detect the drift instead of mis-slicing results.
    if !std::path::Path::new("artifacts/pca_project.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let dir = tmpdir("shape_drift");
    std::fs::copy("artifacts/pca_project.hlo.txt", dir.join("p.hlo.txt")).unwrap();
    std::fs::write(
        dir.join("manifest.toml"),
        // true shapes are [64,1024]x[1024,1024] -> [64,1024]
        "[artifacts.p]\nfile = \"p.hlo.txt\"\ninputs = [\"f32:64x1024\", \"f32:1024x1024\"]\noutputs = [\"f32:64x512\"]\n",
    )
    .unwrap();
    let engine = Engine::new(&dir).unwrap();
    let x = opdr::runtime::ArrayF32::zeros(&[64, 1024]);
    let w = opdr::runtime::ArrayF32::zeros(&[1024, 1024]);
    let err = engine.execute("p", &[x, w]).unwrap_err().to_string();
    assert!(err.contains("elems") || err.contains("output"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coordinator_survives_failing_requests_and_keeps_serving() {
    let coord = Coordinator::start(ServeConfig { workers: 2, ..Default::default() }).unwrap();
    coord.create_collection("ok", 8, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Flickr30k, 30, 8, 1);
    coord.ingest("ok", set.data().to_vec()).unwrap();

    // A burst of failures: wrong collection, wrong dims, zero-k.
    for _ in 0..20 {
        assert!(coord.search("nope", vec![0.0; 8], 3).is_err());
        assert!(coord.search("ok", vec![0.0; 5], 3).is_err()); // wrong dim
    }
    // Still healthy.
    let res = coord.search("ok", set.vector(2).to_vec(), 3).unwrap();
    assert_eq!(res.neighbors[0].index, 2);
    coord.shutdown();
}

#[test]
fn wrong_dim_query_rejected_not_mis_scored() {
    let coord = Coordinator::start(ServeConfig::default()).unwrap();
    coord.create_collection("c", 16, Metric::SqEuclidean).unwrap();
    let set = synth::generate(DatasetKind::Esc50, 20, 16, 2);
    coord.ingest("c", set.data().to_vec()).unwrap();
    let err = coord.search("c", vec![0.0; 15], 3);
    assert!(err.is_err());
    let err = coord.search("c", vec![0.0; 17], 3);
    assert!(err.is_err());
    coord.shutdown();
}

#[test]
fn store_load_of_truncated_file_errors() {
    let dir = tmpdir("store");
    let set = synth::generate(DatasetKind::Flickr30k, 5, 4, 3);
    let path = dir.join("x.opdr");
    opdr::data::store::save(&set, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(opdr::data::store::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reducer_rejects_degenerate_inputs_cleanly() {
    use opdr::reduction::ReducerKind;
    // All-identical points: PCA/MDS must not panic (zero variance).
    let data = vec![1.0f32; 10 * 6];
    for kind in [ReducerKind::Pca, ReducerKind::ClassicalMds, ReducerKind::Smacof] {
        match kind.build(0).fit_transform(&data, 6, 2) {
            Ok(out) => assert!(out.iter().all(|x| x.is_finite()), "{}", kind.name()),
            Err(_) => {} // clean error is acceptable; panic is not
        }
    }
    // NaN inputs: must error, not propagate silently through eigh.
    let mut nan_data = vec![0.5f32; 8 * 4];
    nan_data[5] = f32::NAN;
    assert!(ReducerKind::Pca.build(0).fit_transform(&nan_data, 4, 2).is_err());
}
