//! The lock-rank sentinel, end to end: a deliberate two-lock inversion is
//! caught **twice** — at runtime by the debug-only thread-local rank stack
//! in `util::sync` (a named panic before the deadlock can form), and at
//! lint time by `opdr-lint analyze`, which flags the same source shape as
//! a rank-table violation. CI runs this suite in a debug (non-release)
//! job; in release builds the runtime half compiles out, exactly like the
//! sentinel itself.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use opdr::util::{lock_recover_ranked, ranks, LOCK_RANK_TABLE};

/// The inversion the static pass and the sentinel must both reject:
/// `coordinator.state` (rank 20) acquired while `dist.gateway` (rank 40)
/// is held. The rank table says state-before-gateway, so this is the
/// downhill half of an AB/BA deadlock.
#[cfg(debug_assertions)]
#[test]
fn sentinel_catches_a_two_lock_inversion_at_runtime() {
    let state = Mutex::new(0u64);
    let gateway = Mutex::new(0u64);

    let res = catch_unwind(AssertUnwindSafe(|| {
        let g = lock_recover_ranked(&gateway, ranks::DIST_GATEWAY);
        let s = lock_recover_ranked(&state, ranks::COORDINATOR_STATE);
        *s + *g
    }));
    let err = res.expect_err("the inversion must panic before deadlocking");
    let msg = err.downcast_ref::<String>().expect("panic carries a message");
    assert!(msg.contains("lock-rank inversion"), "unexpected message: {msg}");
    assert!(
        msg.contains("coordinator.state") && msg.contains("dist.gateway"),
        "the panic must name both sites: {msg}"
    );

    // The unwound stack is consistent: the same thread can immediately take
    // the locks in the table's order.
    let ok = catch_unwind(AssertUnwindSafe(|| {
        let s = lock_recover_ranked(&state, ranks::COORDINATOR_STATE);
        let g = lock_recover_ranked(&gateway, ranks::DIST_GATEWAY);
        *s + *g
    }));
    assert!(ok.is_ok(), "in-order acquisition must succeed after the panic");
}

/// The same inversion, fed to the static pass against the *live* rank
/// table — `opdr-lint analyze` flags it without running anything.
#[test]
fn analyzer_flags_the_same_inversion_statically() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let table_src = std::fs::read_to_string(root.join("src/util/sync.rs"))
        .expect("reading the live rank table");

    let inverted = r#"
fn refresh(s: &S) {
    let g = lock_recover_ranked(&s.gateway, ranks::DIST_GATEWAY);
    let st = lock_recover_ranked(&s.state, ranks::COORDINATOR_STATE);
    st.merge(&g);
}
"#;
    let findings = opdr_lint::analyze_sources(&[
        (std::path::PathBuf::from("rust/src/util/sync.rs"), table_src),
        (std::path::PathBuf::from("rust/src/coordinator/fixture.rs"), inverted.to_string()),
    ]);
    assert!(
        findings.iter().any(|f| f.rule == "rank-table-sync"
            && f.msg.contains("strictly increasing")
            && f.msg.contains("coordinator.state")
            && f.msg.contains("dist.gateway")),
        "the static pass must flag the inversion; got:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

/// The public table constant and the `ranks::` module agree — the docs
/// table readers see is the same data the sentinel enforces.
#[test]
fn rank_table_is_strictly_increasing_and_unique() {
    assert!(!LOCK_RANK_TABLE.is_empty());
    for pair in LOCK_RANK_TABLE.windows(2) {
        assert!(
            pair[0].rank < pair[1].rank,
            "LOCK_RANK_TABLE must be sorted strictly by rank: {} vs {}",
            pair[0].name,
            pair[1].name
        );
    }
    let mut names: Vec<&str> = LOCK_RANK_TABLE.iter().map(|r| r.name).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), LOCK_RANK_TABLE.len(), "site names must be unique");
}
