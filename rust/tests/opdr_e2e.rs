//! End-to-end OPDR math: the paper's pipeline run as a library user would.

use opdr::data::{synth, DatasetKind};
use opdr::embed::{embed_records, HashEncoder, ModelKind};
use opdr::metrics::Metric;
use opdr::opdr::{accuracy, fit_log_model, sweep::SweepConfig, Planner};
use opdr::reduction::ReducerKind;

#[test]
fn paper_pipeline_sweep_fit_plan_verify() {
    // 1. "Extract" embeddings (synthetic materials set, CLIP-dim).
    let set = synth::generate(DatasetKind::MaterialsObservable, 120, 256, 42);

    // 2. Sweep accuracy vs n/m (the paper's Figures 1-4 engine).
    let cfg = SweepConfig {
        sample_sizes: vec![30, 60],
        dims_per_m: 8,
        repeats: 2,
        ..Default::default()
    };
    let curve = opdr::opdr::accuracy_curve(&set, &cfg).unwrap();

    // 3. Fit Eq. (4).
    let fit = fit_log_model(curve.points()).unwrap();
    assert!(fit.c0 > 0.0, "accuracy must increase with n/m (c0 = {})", fit.c0);
    assert!(fit.r_squared > 0.5, "log model should explain the sweep (R² = {})", fit.r_squared);

    // 4. Plan a dimension for A=0.85 and verify the measured accuracy is in
    //    the right neighbourhood.
    let planner = Planner::from_fit(fit);
    let m = 60;
    let planned = planner.dim_for_accuracy(0.85, m);
    let sub: Vec<usize> = (0..m).collect();
    let subset = set.subset(&sub).unwrap();
    let n = planned.min(set.dim());
    let reduced = ReducerKind::Pca.build(0).fit_transform(subset.data(), set.dim(), n).unwrap();
    let measured =
        accuracy(subset.data(), set.dim(), &reduced, n, cfg.k, cfg.metric).unwrap();
    assert!(
        measured > 0.85 - 0.15,
        "planned dim {planned} delivered accuracy {measured}, target 0.85"
    );
}

#[test]
fn pca_dominates_random_projection() {
    // The structural claim behind choosing PCA: structure-aware reduction
    // preserves neighbors better than oblivious projection at equal dims.
    let set = synth::generate(DatasetKind::MaterialsStable, 60, 128, 7);
    let k = 5;
    let n = 8;
    let pca = ReducerKind::Pca.build(0).fit_transform(set.data(), 128, n).unwrap();
    let rp = ReducerKind::RandomProjection.build(0).fit_transform(set.data(), 128, n).unwrap();
    let a_pca = accuracy(set.data(), 128, &pca, n, k, Metric::SqEuclidean).unwrap();
    let a_rp = accuracy(set.data(), 128, &rp, n, k, Metric::SqEuclidean).unwrap();
    assert!(a_pca > a_rp, "pca {a_pca} !> random {a_rp}");
}

#[test]
fn trend_holds_across_all_seven_datasets() {
    // Every figure's qualitative claim: accuracy rises with n/m everywhere.
    for kind in DatasetKind::ALL {
        let set = synth::generate(kind, 60, 128, 3);
        let cfg = SweepConfig {
            sample_sizes: vec![40],
            dims_per_m: 6,
            repeats: 1,
            ..Default::default()
        };
        let curve = opdr::opdr::accuracy_curve(&set, &cfg).unwrap();
        let fit = fit_log_model(curve.points()).unwrap();
        assert!(fit.c0 > 0.0, "{}: c0 = {}", kind.name(), fit.c0);
    }
}

#[test]
fn trend_holds_across_models_via_embed_pipeline() {
    // Figs 7-9 shape: all three models produce the log trend on the same raw
    // records (hash-encoder backend; the runtime backend is covered in
    // runtime_it.rs).
    let recs = opdr::data::records::generate_records(DatasetKind::Flickr30k, 60, 5);
    let enc = HashEncoder::default();
    for model in ModelKind::FIGURE_MODELS {
        let set = embed_records(&enc, model, &recs, "e2e").unwrap();
        let cfg = SweepConfig {
            sample_sizes: vec![40],
            dims_per_m: 6,
            repeats: 1,
            ..Default::default()
        };
        let curve = opdr::opdr::accuracy_curve(&set, &cfg).unwrap();
        let fit = fit_log_model(curve.points()).unwrap();
        assert!(fit.c0 > 0.0, "{}: c0 = {}", model.name(), fit.c0);
    }
}

#[test]
fn op2_implies_not_op1_end_to_end() {
    // The paper's non-inclusiveness claim survives the full pipeline: find a
    // reduction where some point's 2-NN set is preserved but its 1-NN is not.
    let set = synth::generate(DatasetKind::Flickr30k, 50, 64, 11);
    let reduced = ReducerKind::Pca.build(0).fit_transform(set.data(), 64, 3).unwrap();
    let s1 = opdr::opdr::measure::NeighborSets::compute(
        set.data(), 64, &reduced, 3, 1, Metric::SqEuclidean).unwrap();
    let s2 = opdr::opdr::measure::NeighborSets::compute(
        set.data(), 64, &reduced, 3, 2, Metric::SqEuclidean).unwrap();
    let mut found = false;
    for i in 0..set.len() {
        let p1 = opdr::opdr::measure::preserved_count(&s1, i);
        let p2 = opdr::opdr::measure::preserved_count(&s2, i);
        if p2 == 2 && p1 == 0 {
            found = true;
            break;
        }
    }
    // This is probabilistic but overwhelmingly likely at this distortion
    // level; if it flakes, the seed can be fixed differently.
    assert!(found, "no OP_2-but-not-OP_1 point found (unlikely but possible)");
}
