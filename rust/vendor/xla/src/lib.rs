//! API-compatible stub of the `xla` PJRT bindings used by the OPDR runtime.
//!
//! The offline build environment has no XLA/PJRT shared libraries, so this
//! crate provides just enough of the binding surface for the `opdr` crate to
//! compile and for its runtime layer to fail *loudly and lazily*: client
//! construction and manifest handling work, but loading an HLO artifact
//! returns an error. The coordinator already treats a failed engine as
//! "runtime disabled" and falls back to the pure-Rust scoring path, so the
//! system degrades gracefully.
//!
//! Swapping this path dependency for the real `xla` bindings re-enables the
//! PJRT execution path with no changes to `opdr` itself.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    /// Construct from a message.
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} unavailable (offline build without PJRT; \
         swap rust/vendor/xla for the real bindings to enable it)"
    ))
}

/// PJRT client handle. Construction succeeds so that manifest-level engine
/// operations (validation, lazy artifact errors) behave like the real crate.
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client. Always succeeds in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Platform name advertised by the client.
    pub fn platform_name(&self) -> String {
        "cpu (xla stub)".to_string()
    }

    /// Compile a computation. Unreachable in practice because HLO loading
    /// fails first; errors defensively if called.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compilation"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file. The stub reports a missing file distinctly
    /// from its own lack of a parser, so failure-injection tests see the
    /// same error classes as with the real bindings.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("hlo artifact not found: {path}")));
        }
        Err(unavailable("HLO parsing"))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a proto (no-op in the stub).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with positional literal arguments.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execution"))
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// A host-side tensor literal.
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literals"))
    }

    /// Read out the payload as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("literal readback"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_and_names_platform() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
    }

    #[test]
    fn missing_hlo_file_reported_distinctly() {
        let e = HloModuleProto::from_text_file("/definitely/not/here.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("not found"), "{e}");
    }

    #[test]
    fn present_hlo_file_fails_with_stub_error() {
        let dir = std::env::temp_dir().join(format!("xla_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.hlo.txt");
        std::fs::write(&p, "HloModule toy").unwrap();
        let e = HloModuleProto::from_text_file(p.to_str().unwrap()).unwrap_err();
        assert!(e.to_string().contains("stub"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
